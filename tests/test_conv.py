"""Implicit-GEMM convolution coverage (DESIGN.md §9).

The conv frontend (`cim_conv2d`) must be **bit-identical** to the
materialized oracle — `_im2col + cim_linear` / `im2col + cim_matmul` —
on the integer (hardware) paths, fp32-close on the exact/surrogate
paths, route through the conv registry universe, and execute through
the zero-retrace executable cache like every other frontend.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import approx_gemm, autotune
from repro.core.approx_gemm import (ConvParams, GemmParams, cim_conv2d,
                                    cim_matmul, conv_out_hw, im2col_nhwc,
                                    plan_conv, select_conv_kernel,
                                    trace_count)
from repro.core.multipliers import MultiplierSpec

# (family, n_approx_cols, expected hardware kernel): every conv kernel
# family, incl. both LUT layouts via the nibble predicate
HW_CASES = [
    ("exact", None, "pallas_conv_nibble"),
    ("appro42", None, "pallas_conv_lut"),
    ("appro42", 4, "pallas_conv_nibble"),
    ("mitchell", None, "pallas_conv_log"),
    ("log_our", None, "pallas_conv_log"),
]

# randomized-ish shape sweep: ragged B/H/W/C/N, every tap count the CNN
# zoo uses, plus stride 2 (bit-exactness needs stride <= min(kh, kw))
SHAPES = [
    # (b, h, w, c, n, kh, kw, stride)
    (2, 9, 10, 5, 7, 3, 3, 1),
    (1, 7, 7, 3, 4, 5, 5, 1),
    (3, 8, 6, 4, 5, 1, 1, 1),
    (2, 10, 9, 3, 6, 3, 3, 2),
]


def _ops(b, h, w, c, n, kh, kw, seed=0):
    kx, kw_ = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, h, w, c))
    wt = jax.random.normal(kw_, (kh * kw * c, n))
    return x, wt


def _oracle(x, wt, gp, cp: ConvParams, key=None):
    cols = im2col_nhwc(x, cp)
    out = cim_matmul(cols.reshape(-1, cols.shape[-1]), wt, gp, key)
    return out.reshape(cols.shape[:3] + (wt.shape[-1],))


# ------------------------------------------------------------- routing ----


def test_conv_routing_per_family():
    for family, nac, kernel in HW_CASES:
        spec = MultiplierSpec(family, 8, True, n_approx_cols=nac)
        assert select_conv_kernel(family, "hardware", 8, spec=spec).name \
            == kernel
    assert select_conv_kernel("exact", "exact", 8).name == "pallas_conv_mxu"
    # spec-less routing stays conservative (predicate entries skipped)
    assert select_conv_kernel("exact", "hardware", 8).name \
        == "pallas_conv_lut"
    # no implicit kernel covers the surrogates: materialized fallback
    assert select_conv_kernel("log_our", "surrogate", 8).name \
        == "conv_im2col"
    assert select_conv_kernel("appro42", "bit_exact", 8).name \
        == "conv_im2col"


def test_conv_plan_falls_back_when_plane_exceeds_vmem():
    """A 224x224 plane cannot sit in VMEM: the plan must degrade to the
    materialized im2col path instead of routing an OOM kernel."""
    spec = MultiplierSpec("exact", 8, True)
    small = plan_conv("exact", "hardware", 8, 4, 16, 16, 16, 16,
                      ConvParams(3, 3, 1), spec=spec)
    big = plan_conv("exact", "hardware", 8, 4, 224, 224, 64, 64,
                    ConvParams(3, 3, 1), spec=spec)
    assert small.entry.name == "pallas_conv_nibble"
    assert big.entry.name == "conv_im2col"


def test_conv_plan_enforces_bit_bound_stride_limit():
    """Geometries where some input pixel reaches no patch (stride >
    min(kh, kw), or a sampling residue beyond the padding) can make
    quant_scale(x) differ from the oracle's quant_scale(im2col(x)):
    routing must honor the declared bit bound by materializing."""
    spec = MultiplierSpec("exact", 8, True)
    ok = plan_conv("exact", "hardware", 8, 2, 13, 13, 4, 4,
                   ConvParams(3, 3, 3), spec=spec)
    assert ok.entry.name == "pallas_conv_nibble"   # residue 0: covered
    over = plan_conv("exact", "hardware", 8, 2, 13, 13, 4, 4,
                     ConvParams(3, 3, 4), spec=spec)
    assert over.entry.name == "conv_im2col"
    # stride <= taps but residue (12+2-3) % 3 = 2 > kh//2: the last
    # real row/col is never sampled — the gate sees the ACTUAL dims
    # (12 and 13 share a shape bucket, so bucketing would miss this)
    res = plan_conv("exact", "hardware", 8, 2, 12, 12, 4, 4,
                    ConvParams(3, 3, 3), spec=spec)
    assert res.entry.name == "conv_im2col"
    # and the frontend result therefore stays bit-identical even there
    gp = GemmParams(family="exact", bits=8, mode="hardware")
    for (hh, ss) in ((13, 4), (12, 3)):
        x, wt = _ops(2, hh, hh, 4, 4, 3, 3, seed=70 + hh)
        got = cim_conv2d(x, wt, gp, stride=ss)
        want = _oracle(x, wt, gp, ConvParams(3, 3, ss))
        assert (np.asarray(got) == np.asarray(want)).all()


def test_conv_params_reject_even_kernels_and_bad_stride():
    with pytest.raises(ValueError, match="even conv kernels"):
        ConvParams(2, 2, 1)
    with pytest.raises(ValueError, match="stride"):
        ConvParams(3, 3, 0)
    with pytest.raises(ValueError):
        from repro.models.cnn import _im2col

        _im2col(jnp.zeros((1, 8, 8, 3)), 4, 4)
    # the low-level kernel wrappers must reject even kernels too, not
    # silently mis-pad them (the bug ConvParams exists to retire)
    from repro.kernels import ops

    with pytest.raises(ValueError, match="even conv kernels"):
        ops.conv2d_mxu_fused(jnp.zeros((1, 8, 8, 3)),
                             jnp.zeros((2 * 2 * 3, 4)), kh=2, kw=2)


# ------------------------------------------------- oracle equivalence ----


@pytest.mark.parametrize("family,nac,kernel", HW_CASES)
def test_hardware_conv_bit_matches_im2col_oracle(family, nac, kernel):
    """The implicit-GEMM kernels gather patches with index arithmetic;
    the result must equal the materialized im2col + GEMM path bit for
    bit, across ragged shapes, every tap count and stride 2."""
    gp = GemmParams(family=family, bits=8, mode="hardware",
                    n_approx_cols=nac)
    for i, (b, h, w, c, n, kh, kw, s) in enumerate(SHAPES):
        cp = ConvParams(kh, kw, s)
        plan = plan_conv(family, "hardware", 8, b, h, w, c, n, cp,
                         spec=gp.spec)
        assert plan.entry.name == kernel, (plan.entry.name, kernel)
        x, wt = _ops(b, h, w, c, n, kh, kw, seed=i)
        got = cim_conv2d(x, wt, gp, kh=kh, kw=kw, stride=s)
        want = _oracle(x, wt, gp, cp)
        assert (np.asarray(got) == np.asarray(want)).all(), \
            f"{family}/{nac} diverged at shape {(b, h, w, c, n, kh, kw, s)}"


def test_exact_mode_conv_matches_oracle_fp32():
    gp = GemmParams(family="exact", bits=8, mode="exact")
    for i, (b, h, w, c, n, kh, kw, s) in enumerate(SHAPES):
        x, wt = _ops(b, h, w, c, n, kh, kw, seed=10 + i)
        got = cim_conv2d(x, wt, gp, kh=kh, kw=kw, stride=s)
        want = _oracle(x, wt, gp, ConvParams(kh, kw, s))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("family", ["exact", "appro42", "mitchell"])
def test_surrogate_conv_matches_oracle_with_same_key(family):
    """Surrogate conv runs the materialized fallback; with the same key
    it must reproduce the im2col + cim_matmul result exactly (same
    noise draw, same variance law)."""
    gp = GemmParams(family=family, bits=8, mode="surrogate", mu=-0.01,
                    c0=120.0, c1=2e-4)
    key = jax.random.PRNGKey(7)
    x, wt = _ops(2, 8, 8, 4, 6, 3, 3, seed=20)
    got = cim_conv2d(x, wt, gp, key)
    want = _oracle(x, wt, gp, ConvParams(3, 3, 1), key)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_im2col_matches_float_conv():
    """The generalized (kh, kw, stride) im2col agrees with XLA's conv
    for every geometry in the sweep (incl. stride 2 and 1x1)."""
    for b, h, w, c, n, kh, kw, s in SHAPES:
        x, wt = _ops(b, h, w, c, n, kh, kw, seed=30)
        cp = ConvParams(kh, kw, s)
        cols = im2col_nhwc(x, cp)
        want = approx_gemm._float_conv(x, wt, cp)
        got = (cols.reshape(-1, kh * kw * c) @ wt).reshape(want.shape)
        assert cols.shape[1:3] == conv_out_hw(h, w, kh, kw, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------ models/cnn.py integration ----


def test_models_conv2d_fused_matches_materialized_baseline():
    """conv2d(fused=True) and the fused=False im2col + cim_linear
    baseline are the same computation — bit-identical on hardware —
    while exact mode (the QAT configuration) stays on the materialized
    fake-quant path in BOTH forms: its gradient semantics (autodiff
    through the quantizer, quantized operands in the VJP) must not
    silently change under the default fused flag."""
    from repro.models.common import CiMContext, CiMParams, Param

    from repro.models.cnn import conv2d

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 4))
    wt = Param(jax.random.normal(jax.random.PRNGKey(1), (36, 8)), None)
    ctx = CiMContext(CiMParams(mode="hardware", family="appro42", bits=8))
    fused = conv2d(wt, x, ctx, "c", fused=True)
    base = conv2d(wt, x, ctx, "c", fused=False)
    assert (np.asarray(fused) == np.asarray(base)).all()

    ctx_ex = CiMContext(CiMParams(mode="exact", bits=8))

    def loss(form):
        def f(xv, wv):
            return jnp.sum(
                conv2d(Param(wv, None), xv, ctx_ex, "c", fused=form) ** 2)
        return jax.grad(f, argnums=(0, 1))(x, wt.value)

    for g_fused, g_base in zip(loss(True), loss(False)):
        assert (np.asarray(g_fused) == np.asarray(g_base)).all(), \
            "exact-mode QAT gradients changed under fused=True"


def test_models_conv2d_mixed_allocation_runs_exact_macro():
    """apply_to prefixes that exclude a conv must drop it to the exact
    int8 macro with cim_linear's fake-quant semantics — identical to
    the materialized path, and different from the approximate family."""
    from repro.models.common import CiMContext, CiMParams, Param

    from repro.models.cnn import conv2d

    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, 6, 3))
    wt = Param(jax.random.normal(jax.random.PRNGKey(3), (27, 4)), None)
    ctx = CiMContext(CiMParams(mode="hardware", family="mitchell", bits=8,
                               apply_to=("mlp",)))
    got = conv2d(wt, x, ctx, "c1", fused=True)
    base = conv2d(wt, x, ctx, "c1", fused=False)
    assert (np.asarray(got) == np.asarray(base)).all()
    applied = conv2d(wt, x, CiMContext(CiMParams(
        mode="hardware", family="mitchell", bits=8)), "c1", fused=True)
    assert not (np.asarray(got) == np.asarray(applied)).all()


def test_cnn_forward_hardware_end_to_end():
    from repro.models.cnn import cnn_forward, init_cnn
    from repro.models.common import CiMContext, CiMParams

    params = init_cnn(jax.random.PRNGKey(0), n_classes=10, width=8)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 16, 16, 3))
    ctx = CiMContext(CiMParams(mode="hardware", family="appro42", bits=8))
    logits = cnn_forward(params, x, ctx)
    assert logits.shape == (2, 10) and bool(jnp.isfinite(logits).all())


def test_conv_grads_match_float_conv_vjp():
    """STE backward must be the exact float conv's VJP."""
    gp = GemmParams(family="exact", bits=8, mode="hardware")
    x, wt = _ops(2, 6, 6, 3, 4, 3, 3, seed=40)
    cp = ConvParams(3, 3, 1)

    g = jax.random.normal(jax.random.PRNGKey(9), (2, 6, 6, 4))
    _, vjp = jax.vjp(lambda a, b: approx_gemm._float_conv(a, b, cp), x, wt)
    want_gx, want_gw = vjp(g)

    def loss(xv, wv):
        return jnp.sum(cim_conv2d(xv, wv, gp) * g)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, wt)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(want_gx),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(want_gw),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------- executable cache ----


def test_conv_zero_retrace_on_repeated_calls():
    gp = GemmParams(family="appro42", bits=8, mode="hardware")
    x, wt = _ops(2, 8, 8, 4, 6, 3, 3, seed=50)
    cim_conv2d(x, wt, gp)                      # build + compile
    t0 = trace_count()
    for _ in range(4):
        cim_conv2d(x, wt, gp)
    assert trace_count() == t0, "cached eager conv calls retraced"
    # same bucket, different batch: still no retrace of the *forward*
    # builder (jit respecializes the shape but reuses the executable
    # entry); a new bucket is allowed to trace
    n0 = approx_gemm.executable_cache_size()
    cim_conv2d(x[:1], wt, gp)
    assert approx_gemm.executable_cache_size() == n0


def test_conv_cached_matches_uncached():
    gp = GemmParams(family="log_our", bits=8, mode="hardware")
    x, wt = _ops(2, 7, 9, 5, 4, 3, 3, seed=60)
    a = cim_conv2d(x, wt, gp)
    b = cim_conv2d(x, wt, gp, cached=False)
    assert (np.asarray(a) == np.asarray(b)).all()


# ----------------------------------------------------------- autotune ----


def test_conv_autotune_sweep_persists_and_caches(tmp_path):
    cache = os.path.join(tmp_path, "tune.json")
    calls = []

    def fake_measure(block):
        calls.append(block)
        bb, bc, bn = block
        return abs(bb - 8) + abs(bc - 64) + abs(bn - 128) + 1.0

    autotune.clear_memory_cache()
    best = autotune.best_conv_block("pallas_conv_nibble", 8, 64, 16, 16,
                                    64, 128, backend="tpu",
                                    measure=fake_measure, cache_file=cache)
    assert best == (8, 64, 128)
    assert len(calls) == len(
        autotune.candidate_conv_blocks("pallas_conv_nibble", 64, 64, 128))
    # second resolve: disk hit, measure never invoked
    autotune.clear_memory_cache()
    calls.clear()
    again = autotune.best_conv_block("pallas_conv_nibble", 8, 64, 16, 16,
                                     64, 128, backend="tpu",
                                     measure=fake_measure, cache_file=cache)
    assert again == best and not calls


@pytest.mark.parametrize("garbage", ["{not json", '{"k": [1, "a", 3]}'])
def test_conv_autotune_corrupt_cache_hardening(tmp_path, garbage):
    """The conv resolver shares best_block's hardened loader: a corrupt
    cache file is ignored and rewritten, never fatal."""
    cache = os.path.join(tmp_path, "tune.json")
    with open(cache, "w") as fh:
        fh.write(garbage)
    autotune.clear_memory_cache()
    best = autotune.best_conv_block("pallas_conv_log", 8, 16, 16, 16, 16,
                                    32, backend="tpu",
                                    measure=lambda b: float(sum(b)),
                                    cache_file=cache)
    assert best in autotune.candidate_conv_blocks("pallas_conv_log", 16,
                                                  16, 32)
    with open(cache) as fh:
        disk = json.load(fh)
    assert list(disk.values()) == [list(best)]


def test_conv_bucket_keeps_taps_and_stride_exact():
    assert autotune.bucket_conv(3, 9, 10, 5, 3, 3, 2) \
        == (8, 16, 16, 8, 3, 3, 2)
    k1 = autotune.conv_cache_key("pallas_conv_lut", 8, 3, 9, 10, 5, 7,
                                 3, 3, 1, "cpu")
    k2 = autotune.conv_cache_key("pallas_conv_lut", 8, 4, 12, 12, 6, 7,
                                 3, 3, 1, "cpu")
    assert k1 == k2                    # same bucket, one plan
    k3 = autotune.conv_cache_key("pallas_conv_lut", 8, 3, 9, 10, 5, 7,
                                 5, 5, 1, "cpu")
    assert k1 != k3                    # taps change the index arithmetic


def test_conv_autotune_off_tpu_never_writes_disk(tmp_path, monkeypatch):
    cache = os.path.join(tmp_path, "never.json")
    monkeypatch.setenv("OPENACM_AUTOTUNE_CACHE", cache)
    autotune.clear_memory_cache()
    blk = autotune.best_conv_block("pallas_conv_lut", 8, 4, 16, 16, 3, 16,
                                   backend="cpu")
    assert blk == autotune.heuristic_conv_block("pallas_conv_lut", 4, 3, 16)
    assert not os.path.exists(cache)
