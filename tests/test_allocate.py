"""Per-module accuracy allocation (DESIGN.md §16, ISSUE 10): alloc
plumbing through CiMConfig/cim_linear, the probe + mixing evaluator,
`autoallocate` against the exhaustive oracle, and the serving lane."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import allocate
from repro.core.compiler import CiMConfig
from repro.models.common import CiMParams
from repro.models.transformer import LM

ARCH = "qwen3-1.7b"
MODS = ("wq", "wv", "mlp_wo")       # 3 modules x 4 tiers: exhaustible
ALL_MODS = ("wq", "wk", "wv", "wo", "mlp_wi", "mlp_wg", "mlp_wo")


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_config(ARCH, smoke=True)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)}
    return cfg, lm, params, batch


@pytest.fixture(scope="module")
def evaluator(smoke_lm):
    _, lm, params, batch = smoke_lm
    return allocate.make_evaluator(lm, params=params, batch=batch,
                                   modules=MODS)


# ------------------------------------------------------- alloc plumbing --


def test_cim_config_alloc_validation():
    ok = CiMConfig(alloc=(("mlp", "appro42", "orplane", 10),))
    assert ok.alloc == (("mlp", "appro42", "orplane", 10),)
    with pytest.raises(ValueError, match="mutually exclusive"):
        CiMConfig(alloc=(("mlp", "appro42", "yang1", 8),),
                  apply_to=("mlp",))
    with pytest.raises(ValueError, match="4-tuples"):
        CiMConfig(alloc=(("mlp", "appro42"),))
    with pytest.raises(ValueError, match="non-empty str"):
        CiMConfig(alloc=(("", "appro42", "yang1", 8),))
    with pytest.raises(ValueError, match="not in"):
        CiMConfig(alloc=(("mlp", "booth", "yang1", 8),))
    with pytest.raises(ValueError, match="n_approx_cols"):
        CiMConfig(alloc=(("mlp", "appro42", "yang1", -3),))


def test_alloc_longest_prefix_routing():
    p = CiMParams.from_config(CiMConfig(
        family="appro42", bits=8, mode="surrogate",
        alloc=(("mlp", "appro42", "orplane", 10),
               ("mlp_wo", "log_our", "yang1", None),
               ("wq", "exact", "yang1", None))))
    gp, apply = p.routing("mlp_wi")
    assert (gp.family, gp.compressor, gp.n_approx_cols, apply) == \
        ("appro42", "orplane", 10, True)
    gp, apply = p.routing("mlp_wo")          # longest prefix wins
    assert (gp.family, apply) == ("log_our", True)
    gp, apply = p.routing("wq")              # explicit exact entry
    assert not apply
    gp, apply = p.routing("wk")              # unmatched -> exact macro
    assert not apply
    # frozen GemmParams per module: hashable (executable-cache keys)
    assert hash(p.alloc) is not None


def test_exact_alloc_matches_exact_baseline(smoke_lm):
    """An all-exact alloc table and the apply-nothing baseline run the
    same executables: identical logits."""
    cfg, _, params, batch = smoke_lm
    cfg_a = dataclasses.replace(cfg, cim=CiMConfig(
        family="appro42", bits=8, mode="surrogate",
        alloc=tuple((m, "exact", "yang1", None) for m in ALL_MODS)))
    cfg_b = dataclasses.replace(cfg, cim=CiMConfig(
        family="appro42", bits=8, mode="surrogate",
        apply_to=("__none__",)))
    key = jax.random.PRNGKey(3)
    la = LM(cfg_a).forward_logits(params, batch, key=key)
    lb = LM(cfg_b).forward_logits(params, batch, key=key)
    assert jnp.array_equal(la, lb)


def test_probe_finds_named_modules(smoke_lm):
    _, lm, params, batch = smoke_lm
    stats = allocate.probe_modules(lm, params, batch)
    names = [s.name for s in stats]
    assert set(names) == set(ALL_MODS)
    cfg = lm.cfg
    by = {s.name: s for s in stats}
    assert by["wq"].k == cfg.d_model
    # scanned body: every module executes n_periods times per forward
    assert all(s.calls == cfg.n_periods for s in stats)
    assert all(s.macs > 0 and s.absmax_w > 0 for s in stats)


# -------------------------------------------------- evaluator + search --


def test_evaluator_all_exact_is_zero_nmed(evaluator):
    L = len(evaluator.modules)
    assert evaluator.nmed([0] * L) == 0.0


def test_evaluator_deterministic_and_monotone_sanity(evaluator):
    L = len(evaluator.modules)
    a = [1] * L
    x1 = evaluator.nmed(a)
    x2 = evaluator.nmed(a)
    assert x1 == x2 > 0.0
    # perturbing every module is no better (to noise-cancellation
    # slack) than perturbing one of them at the same tier
    worst = evaluator.nmed([2] * L)
    single = evaluator.nmed([2] + [0] * (L - 1))
    assert worst >= 0.5 * single


def test_autoallocate_within_oracle_energy(smoke_lm, evaluator):
    """ISSUE 10 acceptance: on an exhaustible model the surrogate
    search's allocation energy is within 10% of the true optimum at
    the same NMED budget — and both satisfy the budget exactly."""
    _, lm, _, _ = smoke_lm
    budget = 1e-2
    a = allocate.autoallocate(lm, budget, evaluator=evaluator)
    o = allocate.exhaustive_oracle(lm, budget, evaluator=evaluator)
    assert a.nmed <= budget and o.nmed <= budget
    assert a.energy_per_mac_j <= 1.10 * o.energy_per_mac_j, \
        (f"autoallocate {a.energy_per_mac_j:.4g} J/MAC vs oracle "
         f"{o.energy_per_mac_j:.4g} J/MAC")
    # far fewer exact evaluations than the 4^3 sweep
    assert a.evals < o.evals


@pytest.mark.parametrize("budget", [3e-3, 8e-3, 2e-2])
def test_autoallocate_budget_always_satisfied(smoke_lm, evaluator,
                                              budget):
    """Property (seeded sweep; the hypothesis variant lives below):
    whatever the surrogate predicts, the RETURNED allocation satisfies
    the budget under exact re-evaluation, by construction."""
    _, lm, _, _ = smoke_lm
    a = allocate.autoallocate(lm, budget, evaluator=evaluator)
    assert a.nmed <= budget
    assert a.nmed == evaluator.nmed(
        [ {c.short_name(): i for i, c in
           enumerate(evaluator.candidates)}[t] for _, t in a.tier_map])
    assert a.energy_per_mac_j <= a.exact_energy_per_mac_j


def test_autoallocate_tightest_budget_degrades_to_exact(smoke_lm,
                                                        evaluator):
    _, lm, _, _ = smoke_lm
    a = allocate.autoallocate(lm, 1e-9, evaluator=evaluator)
    assert a.nmed == 0.0
    assert all(t == "exact8b" for _, t in a.tier_map)
    assert a.energy_per_mac_j == a.exact_energy_per_mac_j


def test_allocation_roundtrip_through_cim_config(smoke_lm, evaluator):
    """The returned alloc table drives a real forward whose deviation
    from exact matches the evaluator's measurement to first order."""
    cfg, lm, params, batch = smoke_lm
    a = allocate.autoallocate(lm, 1e-2, evaluator=evaluator)
    cim = a.to_cim_config()
    assert cim.alloc == a.alloc
    lm_a = LM(dataclasses.replace(cfg, cim=cim))
    logits = lm_a.forward_logits(params, batch,
                                 key=jax.random.PRNGKey(5))
    assert bool(jnp.all(jnp.isfinite(logits)))
    exact = LM(dataclasses.replace(cfg, cim=dataclasses.replace(
        cim, alloc=tuple((n, "exact", "yang1", None)
                         for n, *_ in cim.alloc)))).forward_logits(
        params, batch, key=jax.random.PRNGKey(5))
    d = np.abs(np.asarray(logits, np.float32)
               - np.asarray(exact, np.float32))
    nmed = d.mean() / np.abs(np.asarray(exact, np.float32)).max()
    assert 0.0 < nmed < 10 * a.max_nmed


# --------------------------------------------------- serving lane -------


def test_allocation_lane_zero_steady_retraces(smoke_lm, evaluator):
    """The autoallocate tier serves as a pre-jitted lane over shared
    weights: after warmup, mixed exact/autoalloc traffic never
    retraces the dispatch engine (ISSUE 10 acceptance)."""
    from repro.serving.engine import build_engine
    from repro.serving.tiers import allocation_tier, build_tiers
    from repro.serving.workload import poisson_workload

    cfg, lm, params, _ = smoke_lm
    a = allocate.autoallocate(lm, 1e-2, evaluator=evaluator)
    tier = allocation_tier(a, mode="surrogate_fast")
    assert tier.nmed == a.nmed
    tiers = tuple(t for t in build_tiers(families=("exact",))) + (tier,)
    eng = build_engine(cfg, params, tiers=tiers, slots_per_tier=2,
                       max_len=24, prompt_buckets=(6,),
                       group_buckets=(1, 2))
    eng.warmup()
    wl = poisson_workload(6, rate=500.0, vocab=cfg.vocab,
                          prompt_len=(3, 6), max_new=(1, 4),
                          tier_mix=(("exact", None, 1.0),
                                    ("autoalloc", None, 1.0)), seed=9)
    res = eng.run(wl)
    assert all(r.done for r in res.values())
    assert {r.tier for r in res.values()} == {"exact", "autoalloc"}
    assert eng.steady_retraces() == 0, \
        "allocation lane retraced after pre-warm"
