"""Telemetry spine (DESIGN.md §15).

Four layers:

  * instrument primitives — counter/gauge label keying, histogram
    bucketing (Prometheus-inclusive upper bounds + implicit +inf),
    preallocated ring wraparound/drop accounting, and the
    disabled-registry fast path reducing every record to a no-op;
  * exporters — golden Prometheus text exposition, Chrome-trace
    structure (microsecond conversion, per-tid metadata rows), and the
    JSONL event dump round-tripping dataclass events;
  * dispatch-boundary capture — `obs_mac_scale` ambient scaling,
    `MacCapture`/`profile_macs` recovering the exact m*k*n MAC count of
    a GEMM through `jax.eval_shape` (no FLOPs);
  * engine integration on fake lanes (no jax compiles) — request
    lifecycle spans, `engine.metrics()`, structured `TripEvent`s with
    dict back-compat, and retry spans for work a trip displaces —
    plus `EngineStats.from_results` edge cases and the injectable
    serving clocks.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.obs import (EngineTelemetry, MacCapture, MetricsRegistry,
                       Ring, Span, capture_macs, chrome_trace,
                       events_jsonl, profile_macs, prometheus_text)
from repro.obs.metrics import Counter, Gauge, Histogram
from repro.serving import (Clock, EngineStats, RealClock, ServingEngine,
                           SimClock, TripEvent)
from repro.serving.engine import RequestResult
from repro.serving.tiers import TierRouter
from test_serving import FakeLane, _fake_tiers, _req


# ---------------------------------------------------------------------------
# instrument primitives
# ---------------------------------------------------------------------------


def test_counter_labels_and_total():
    c = Counter("x_total")
    c.inc()
    c.inc(2, op="gemm", family="appro42")
    c.inc(3, family="appro42", op="gemm")    # label order-insensitive
    assert c.value() == 1
    assert c.value(op="gemm", family="appro42") == 5
    assert c.value(op="conv") == 0.0
    assert c.total == 6


def test_gauge_last_write_wins():
    g = Gauge("x")
    g.set(1.5, tier="a")
    g.set(2.5, tier="a")
    assert g.value(tier="a") == 2.5
    assert g.value(tier="b") is None


def test_histogram_bucketing_inclusive_bounds():
    h = Histogram("h", buckets=(0.1, 0.3, 1.0))
    for v in (0.05, 0.1, 0.3, 0.7, 5.0):     # bounds are inclusive (le=)
        h.observe(v, tier="a")
    snap = h.snapshot(tier="a")
    assert snap["buckets"] == [(0.1, 2.0), (0.3, 3.0), (1.0, 4.0),
                               (float("inf"), 5.0)]
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(6.15)
    # label sets are independent
    assert h.snapshot(tier="b")["count"] == 0


def test_histogram_rejects_bad_buckets():
    with pytest.raises(ValueError):
        Histogram("h", buckets=())
    with pytest.raises(ValueError):
        Histogram("h", buckets=(1.0, 0.5))


def test_ring_wraparound_and_drop_accounting():
    r = Ring(4)
    for i in range(3):
        r.append(i)
    assert r.items() == [0, 1, 2] and r.dropped == 0
    for i in range(3, 7):
        r.append(i)
    assert len(r) == 4
    assert r.items() == [3, 4, 5, 6]         # oldest dropped, order kept
    assert r.total == 7 and r.dropped == 3
    r.clear()
    assert len(r) == 0 and r.total == 0 and r.items() == []
    with pytest.raises(ValueError):
        Ring(0)


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("c_total")
    h = reg.histogram("h", (1.0,))
    g = reg.gauge("g")
    c.inc(5)
    g.set(1.0)
    h.observe(0.5)
    reg.span("s", 0.0, 1.0)
    reg.event("e", 0.0)
    assert c.total == 0 and g.value() is None
    assert h.snapshot()["count"] == 0
    assert len(reg.spans) == 0 and len(reg.events) == 0


def test_registry_factories_idempotent():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.histogram("h", (1.0,)) is reg.histogram("h", (2.0,))


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_prometheus_text_golden():
    reg = MetricsRegistry()
    c = reg.counter("repro_calls_total", "calls")
    c.inc(3, op="gemm")
    c.inc(1, op="conv")
    reg.gauge("repro_agree", "agreement").set(0.5, tier="a")
    h = reg.histogram("repro_wait_seconds", (0.1, 1.0), "wait")
    h.observe(0.05)
    h.observe(0.5)
    h.observe(7.0)
    assert prometheus_text(reg) == (
        "# HELP repro_calls_total calls\n"
        "# TYPE repro_calls_total counter\n"
        'repro_calls_total{op="conv"} 1\n'
        'repro_calls_total{op="gemm"} 3\n'
        "# HELP repro_agree agreement\n"
        "# TYPE repro_agree gauge\n"
        'repro_agree{tier="a"} 0.5\n'
        "# HELP repro_wait_seconds wait\n"
        "# TYPE repro_wait_seconds histogram\n"
        'repro_wait_seconds_bucket{le="0.1"} 1\n'
        'repro_wait_seconds_bucket{le="1"} 2\n'
        'repro_wait_seconds_bucket{le="+Inf"} 3\n'
        "repro_wait_seconds_sum 7.55\n"
        "repro_wait_seconds_count 3\n")


def test_chrome_trace_structure():
    spans = [Span("decode", 1.0, 0.5, tid=3,
                  labels={"tier": "a", "cat": "serving"}),
             Span("decode_round", 2.0, -0.1, tid=-1, labels={})]
    out = chrome_trace(spans, tid_names={-1: "lane a"})
    assert out["displayTimeUnit"] == "ms"
    evs = out["traceEvents"]
    x = [e for e in evs if e["ph"] == "X"]
    assert x[0]["ts"] == 1e6 and x[0]["dur"] == 5e5
    assert x[0]["args"] == {"tier": "a"}         # cat lifted, not an arg
    assert x[1]["dur"] == 0.0                    # negative dur clamped
    names = {e["tid"]: e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {3: "request 3", -1: "lane a"}
    assert any(e["name"] == "process_name" for e in evs)


def test_events_jsonl_roundtrip(tmp_path):
    ev = TripEvent(lane="a", t=1.0, reason="drift",
                   tokens_before_trip=7, in_flight_displaced=2)
    path = tmp_path / "events.jsonl"
    text = events_jsonl([{"kind": "x", "t": 0.0}, ev], str(path))
    lines = [json.loads(ln) for ln in text.splitlines()]
    assert lines[0] == {"kind": "x", "t": 0.0}
    assert lines[1]["lane"] == "a"
    assert lines[1]["breaker_after"] == "tripped"
    assert path.read_text() == text


def test_trip_event_dict_compat():
    ev = TripEvent(lane="a", t=0.0, reason="r", tokens_before_trip=1,
                   in_flight_displaced=0)
    assert ev["lane"] == "a" and ev["reason"] == "r"
    assert ev.get("missing") is None and ev.get("t", 9) == 0.0
    assert "breaker_before" in ev.keys()
    with pytest.raises(KeyError):
        ev["nope"]


# ---------------------------------------------------------------------------
# dispatch-boundary MAC capture
# ---------------------------------------------------------------------------


def test_obs_mac_scale_nesting():
    from repro.core import approx_gemm

    assert approx_gemm._OBS_MAC_SCALE[0] == 1.0
    with approx_gemm.obs_mac_scale(3):
        assert approx_gemm._OBS_MAC_SCALE[0] == 3.0
        with approx_gemm.obs_mac_scale(2):
            assert approx_gemm._OBS_MAC_SCALE[0] == 6.0
        assert approx_gemm._OBS_MAC_SCALE[0] == 3.0
    assert approx_gemm._OBS_MAC_SCALE[0] == 1.0


def test_profile_macs_gemm_exact_count():
    from repro.core.approx_gemm import GemmParams, cim_matmul

    m, k, n = 5, 16, 8
    gp = GemmParams(family="exact", bits=8, mode="exact")

    def f(x, w):
        return cim_matmul(x, w, gp)

    cap = profile_macs(f, np.zeros((m, k), np.float32),
                       np.zeros((k, n), np.float32))
    assert cap.total == m * k * n
    assert cap.by_family == {("exact", 8): m * k * n}
    assert cap.by_op == {"gemm": m * k * n}


def test_capture_macs_scoped_and_restores_sink():
    from repro.core import approx_gemm
    from repro.core.approx_gemm import GemmParams, cim_matmul

    gp = GemmParams(family="exact", bits=8, mode="exact")
    outer = MacCapture()
    prev = approx_gemm.set_obs_sink(outer)
    try:
        with capture_macs() as cap:
            with approx_gemm.obs_mac_scale(4):  # lax.scan correction
                cim_matmul(np.zeros((2, 4), np.float32),
                           np.zeros((4, 3), np.float32), gp)
        assert cap.total == 4 * 2 * 4 * 3
        assert outer.total == 0                 # scoped: outer untouched
        assert approx_gemm._OBS_SINK[0] is outer
    finally:
        approx_gemm.set_obs_sink(prev)


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------


def test_clock_base_and_impls():
    with pytest.raises(NotImplementedError):
        Clock().now()
    sim = SimClock()
    assert sim.now() == 0.0
    sim.wait_until(2.0)
    sim.wait_until(1.0)                        # never moves backwards
    assert sim.now() == 2.0
    rc = RealClock()
    assert rc.now() >= 0.0
    assert isinstance(sim, Clock) and isinstance(rc, Clock)


# ---------------------------------------------------------------------------
# EngineStats edge cases
# ---------------------------------------------------------------------------


def test_engine_stats_empty_results():
    s = EngineStats.from_results({}, 0.0)
    assert s.n_requests == 0 and s.total_tokens == 0
    assert s.tokens_per_s == 0.0               # zero-duration guarded
    assert s.p50_ms_per_token == 0.0 and s.p50_ttft_ms == 0.0


def test_engine_stats_all_failed():
    rr = RequestResult(rid=0, tier="a", prompt_len=4, arrival=0.0,
                       tokens=[1, 2], t_done=1.0)
    rr.status = "failed"
    s = EngineStats.from_results({0: rr}, 1.0)
    assert s.n_requests == 0                   # ok-completions only
    assert s.n_failed == 1
    assert s.total_tokens == 0                 # failed tokens don't count


def test_engine_stats_ignores_inflight():
    ok = RequestResult(rid=0, tier="a", prompt_len=4, arrival=0.0,
                       tokens=[1, 2, 3], t_first=0.1, t_done=0.5)
    live = RequestResult(rid=1, tier="a", prompt_len=4, arrival=0.2,
                         tokens=[1])           # t_done unset: in flight
    s = EngineStats.from_results({0: ok, 1: live}, 2.0)
    assert s.n_requests == 1 and s.total_tokens == 3
    assert s.tokens_per_s == pytest.approx(1.5)
    assert s.p50_ttft_ms == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# engine integration (fake lanes, no jax)
# ---------------------------------------------------------------------------


def _tel_engine(n_slots=3, names=("a", "b"), **kw):
    tel = EngineTelemetry(attach=False, energy=False)
    tiers = _fake_tiers(names)
    lanes = {t.name: FakeLane(n_slots) for t in tiers}
    eng = ServingEngine(lanes, TierRouter(tiers), check_invariants=True,
                        telemetry=tel, **kw)
    return eng, tel


def test_telemetry_request_lifecycle_spans():
    eng, tel = _tel_engine()
    eng.warmup()
    reqs = [_req(i, tier="ab"[i % 2], max_new=2 + i % 3,
                 arrival=0.01 * i) for i in range(6)]
    res = eng.run(reqs, clock=SimClock())
    assert all(r.done for r in res.values())

    spans = tel.registry.spans.items()
    by_name = {}
    for s in spans:
        by_name.setdefault(s.name, []).append(s)
    # one queue/prefill/decode span per completed request, tid = rid
    for name in ("queue", "prefill", "decode"):
        assert sorted(s.tid for s in by_name[name]) == list(range(6))
    # lane rows are negative and named
    assert all(s.tid < 0 for s in by_name["decode_round"])
    assert set(tel.tid_names.values()) == {"lane a", "lane b"}

    tok_total = sum(len(r.tokens) for r in res.values())
    assert tel.tokens_c.total == tok_total
    assert tel.requests_c.value(tier="a", status="ok") == 3
    assert tel.queue_wait_h.snapshot(tier="b")["count"] == 3

    m = eng.metrics()
    assert m["n_requests"] == 6 and m["n_failed"] == 0
    assert m["total_tokens"] == tok_total
    assert m["steady_retraces"] == 0
    assert set(m["lanes"]) == {"a", "b"}
    la = m["lanes"]["a"]
    assert la["tokens"] == sum(len(res[r.rid].tokens) for r in reqs
                               if r.tier == "a")
    assert la["energy_per_token_j"] is None    # fake lane: no LM surface
    assert la["trips"] == 0 and la["retries"] == 0


def test_telemetry_trip_retry_spans_and_events():
    eng, tel = _tel_engine(retry_backoff_s=0.0)
    eng.warmup()
    for i in range(3):
        eng.submit(_req(i, tier="b", max_new=4))
    eng.step(0.0)                              # admit + first round
    lane = eng.lanes["b"]
    assert lane.running
    n_running = len(lane.running)
    eng._trip(lane, 0.5, "forced (test)")

    ev = eng.trip_log[0]
    assert isinstance(ev, TripEvent)
    assert ev["lane"] == "b" and ev.in_flight_displaced == n_running
    assert ev.breaker_before == "healthy"
    assert ev.breaker_after == "tripped"       # no sentinel: default
    assert ev.trigger_agree is None

    retry_spans = [s for s in tel.registry.spans.items()
                   if s.name == "retry"]
    assert sorted(s.tid for s in retry_spans) == list(range(n_running))
    assert all(s.labels["tier"] == "b" for s in retry_spans)
    assert tel.retries_c.value(tier="b") == n_running
    assert tel.trips_c.value(tier="b") == 1
    kinds = [e["kind"] for e in tel.registry.events.items()]
    assert "sentinel_trip" in kinds and "breaker_transition" in kinds
    trip_ev = next(e for e in tel.registry.events.items()
                   if e["kind"] == "sentinel_trip")
    assert trip_ev["reason"] == "forced (test)"

    # displaced work drains on the surviving lane, counted as retries
    for t in range(1, 40):
        eng.step(0.1 * t)
        if all(r.done for r in eng.results.values()):
            break
    assert all(r.done and r.status == "ok"
               for r in eng.results.values())
    assert all(r.tier == "a" for r in eng.results.values())
    m = eng.metrics()
    assert m["lanes"]["b"]["trips"] == 1
    assert m["lanes"]["b"]["retries"] == n_running
    assert m["lanes"]["b"]["quarantined"] is True


def test_metrics_without_telemetry():
    from test_serving import _fake_engine

    eng, _ = _fake_engine()
    eng.warmup()
    eng.run([_req(i, tier="a", max_new=2) for i in range(3)],
            clock=SimClock())
    m = eng.metrics()
    assert m["n_requests"] == 3
    assert m["lanes"]["a"]["tokens"] == 6
    assert m["lanes"]["a"]["energy_per_token_j"] is None
    assert m["lanes"]["a"]["acceptance_rate"] is None


def test_telemetry_detach_restores_sink():
    from repro.core import approx_gemm, autotune

    prev_g = approx_gemm._OBS_SINK[0]
    prev_a = autotune._OBS_SINK[0]
    tel = EngineTelemetry(energy=False)        # attaches globally
    assert approx_gemm._OBS_SINK[0] is tel
    assert autotune._OBS_SINK[0] is tel
    tel.detach()
    assert approx_gemm._OBS_SINK[0] is None
    assert autotune._OBS_SINK[0] is None
    approx_gemm._OBS_SINK[0] = prev_g
    autotune._OBS_SINK[0] = prev_a


def test_dispatch_sink_protocol_counts():
    tel = EngineTelemetry(attach=False, energy=False)
    tel.dispatch(op="gemm", family="appro42", mode="surrogate_fast",
                 bits=8, macs=100.0, cache_hit=False)
    tel.dispatch(op="gemm", family="appro42", mode="surrogate_fast",
                 bits=8, macs=100.0, cache_hit=True)
    tel.retrace()
    tel.autotune("k", "disk_hit")
    assert tel.dispatch_calls.value(
        op="gemm", family="appro42", mode="surrogate_fast", bits=8,
        cache="miss") == 1
    assert tel.dispatch_calls.value(
        op="gemm", family="appro42", mode="surrogate_fast", bits=8,
        cache="hit") == 1
    assert tel.dispatch_macs.value(op="gemm", family="appro42",
                                   bits=8) == 200.0
    assert tel.retraces.total == 1
    assert tel.autotune_c.value(outcome="disk_hit") == 1
