"""Kernel registry / dispatcher coverage (DESIGN.md §8).

Every (family, mode) pair must route to a registered kernel whose
output matches the kernels/ref.py oracle within the family's documented
error bound: bit-for-bit for the integer paths, fp32-allclose for the
exact/surrogate deterministic terms, and moment-level for the
stochastic surrogate (covered separately in test_error_model.py).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CiMConfig, compile_macro
from repro.core import approx_gemm, autotune
from repro.core.approx_gemm import (FAMILIES, MODES, GemmParams,
                                    cim_matmul, model_matmul, plan_gemm,
                                    run_int_kernel, select_kernel,
                                    registered_kernels, trace_count)
from repro.core.multipliers import MultiplierSpec
from repro.core.quantization import dequantize, quant_scale, quantize
from repro.kernels import ref

ALL_PAIRS = [(f, m) for f in FAMILIES for m in MODES]


def _float_ops(m, k, n, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (m, k)), jax.random.normal(kw, (k, n)))


def _int_ops(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-127, 128, (m, k), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, (k, n), dtype=np.int8))
    return xq, wq


# ------------------------------------------------------------- routing ----


@pytest.mark.parametrize("family,mode", ALL_PAIRS)
def test_every_pair_routes_to_a_kernel(family, mode):
    entry = select_kernel(family, mode, bits=8)
    assert entry.supports(family, mode, 8, jax.default_backend())


@pytest.mark.parametrize("family,mode", ALL_PAIRS)
def test_macro_matmul_executes_every_pair(family, mode):
    macro = compile_macro(CiMConfig(family=family, bits=8, mode=mode))
    x, w = _float_ops(9, 33, 7)
    out = macro.matmul(x, w, key=jax.random.PRNGKey(3))
    assert out.shape == (9, 7) and bool(jnp.isfinite(out).all())


def test_surrogate_routes_to_fused_kernel_on_tpu_only():
    cpu = select_kernel("log_our", "surrogate", 8, backend="cpu")
    tpu = select_kernel("log_our", "surrogate", 8, backend="tpu")
    assert cpu.name == "xla_surrogate"
    assert tpu.name == "pallas_fused_surrogate"


def test_hardware_mode_prefers_arithmetic_kernel_for_log_families():
    assert select_kernel("mitchell", "hardware", 8).name == "pallas_log"
    assert select_kernel("log_our", "hardware", 8).name == "pallas_log"
    assert select_kernel("appro42", "hardware", 8).name == "pallas_lut_gather"
    # without a spec, predicate-gated entries (nibble) are not eligible
    assert select_kernel("exact", "hardware", 8).name == "pallas_lut_gather"


def test_nibble_routing_requires_decomposable_spec():
    """The nibble kernel outranks the full-LUT gather exactly when the
    family's table factorizes bit-exactly into half-word sub-LUTs."""
    exact = MultiplierSpec("exact", 8, True)
    assert select_kernel("exact", "hardware", 8, spec=exact).name \
        == "pallas_lut_nibble"
    # appro42 default approximates columns 0..7: cross sub-products
    # differ from the full tree -> fall back to the k-sliced gather
    a8 = MultiplierSpec("appro42", 8, True)
    assert select_kernel("appro42", "hardware", 8, spec=a8).name \
        == "pallas_lut_gather"
    # approximated columns confined to the low half-word -> decomposable
    a4 = MultiplierSpec("appro42", 8, True, n_approx_cols=4)
    assert select_kernel("appro42", "hardware", 8, spec=a4).name \
        == "pallas_lut_nibble"
    # odd widths never decompose (half-words must be equal width)
    from repro.core.luts import nibble_decomposable

    assert not nibble_decomposable(MultiplierSpec("exact", 9, True))


def test_gemm_params_route_through_nibble_predicate():
    gp = GemmParams(family="exact", bits=8, mode="hardware")
    plan = plan_gemm("exact", "hardware", 8, 16, 16, 16, spec=gp.spec)
    assert plan.entry.name == "pallas_lut_nibble"
    gp8 = GemmParams(family="appro42", bits=8, mode="hardware")
    plan8 = plan_gemm("appro42", "hardware", 8, 16, 16, 16, spec=gp8.spec)
    assert plan8.entry.name == "pallas_lut_gather"


def test_unroutable_request_raises_with_inventory():
    with pytest.raises(ValueError, match="no kernel"):
        # no hardware kernel covers a 20-bit compressor-tree family
        select_kernel("appro42", "hardware", bits=20)
    with pytest.raises(ValueError, match="not in"):
        select_kernel("exact", "warp_drive")


def test_registry_entries_document_oracles():
    for e in registered_kernels():
        assert e.oracle, f"kernel {e.name} lacks an oracle reference"
        assert e.bound in ("bit", "fp32", "stochastic")


# ------------------------------------------------- oracle equivalence ----


@pytest.mark.parametrize("family", ["exact", "appro42"])
def test_hardware_lut_kernel_bit_matches_oracle(family):
    xq, wq = _int_ops(17, 40, 9, seed=1)
    gp = GemmParams(family=family, bits=8, mode="hardware")
    plan = plan_gemm(family, "hardware", 8, 17, 40, 9)
    got = run_int_kernel(plan, xq, wq, gp)
    from repro.core.luts import signed_product_lut

    lut = jnp.asarray(signed_product_lut(gp.spec).ravel())
    want = ref.lut_matmul_ref(xq, wq, lut)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("family,nac", [("exact", None), ("appro42", 4)])
def test_nibble_kernel_bit_matches_oracle(family, nac):
    """The nibble-decomposed kernel is bit-identical to the full-LUT
    oracle for every spec it routes (ragged shape exercises padding)."""
    xq, wq = _int_ops(17, 40, 9, seed=3)
    gp = GemmParams(family=family, bits=8, mode="hardware",
                    n_approx_cols=nac)
    plan = plan_gemm(family, "hardware", 8, 17, 40, 9, spec=gp.spec)
    assert plan.entry.name == "pallas_lut_nibble"
    got = run_int_kernel(plan, xq, wq, gp)
    from repro.core.luts import signed_product_lut

    lut = jnp.asarray(signed_product_lut(gp.spec).ravel())
    want = ref.lut_matmul_ref(xq, wq, lut)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("family", ["mitchell", "log_our"])
def test_hardware_log_kernel_bit_matches_oracle(family):
    xq, wq = _int_ops(17, 40, 9, seed=2)
    gp = GemmParams(family=family, bits=8, mode="hardware")
    plan = plan_gemm(family, "hardware", 8, 17, 40, 9)
    got = run_int_kernel(plan, xq, wq, gp)
    want = ref.mitchell_matmul_ref(xq, wq,
                                   compensated=(family == "log_our"))
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("family", FAMILIES)
def test_hardware_mode_equals_bit_exact_mode(family):
    """The Pallas kernels and the jnp LUT oracle implement the same
    integer semantics (quantization clips to [-127, 127], so the
    sign-magnitude -128 edge case never arises)."""
    macro = compile_macro(CiMConfig(family=family, bits=8))
    x, w = _float_ops(13, 29, 11, seed=4)
    be = macro.matmul(x, w, mode="bit_exact")
    hw = macro.matmul(x, w, mode="hardware")
    np.testing.assert_allclose(np.asarray(be), np.asarray(hw),
                               rtol=1e-6, atol=1e-6)


def test_exact_mode_is_quantize_dequantize_dot():
    macro = compile_macro(CiMConfig(family="exact", bits=8, mode="exact"))
    x, w = _float_ops(8, 32, 4, seed=5)
    got = macro.matmul(x, w)
    sx = quant_scale(x, 8)
    sw = quant_scale(w, 8, axis=0)
    want = dequantize(quantize(x, sx, 8), sx) @ dequantize(
        quantize(w, sw, 8), sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_surrogate_without_key_is_deterministic_bias_term():
    macro = compile_macro(CiMConfig(family="log_our", bits=8))
    x, w = _float_ops(8, 64, 8, seed=6)
    a = macro.matmul(x, w)                 # key=None: no noise drawn
    b = macro.matmul(x, w)
    assert (np.asarray(a) == np.asarray(b)).all()
    gp = macro.gemm_params()
    exact = macro.matmul(x, w, mode="exact")
    np.testing.assert_allclose(np.asarray(a),
                               (1.0 + gp.mu) * np.asarray(exact),
                               rtol=3e-5, atol=3e-5)


def test_model_path_hardware_matches_macro_path():
    """cim_linear (model frontend) and CiMMacro.matmul (macro frontend)
    execute the same routed kernel for hardware mode."""
    from repro.models.common import CiMContext, CiMParams, Param, cim_linear

    macro = compile_macro(CiMConfig(family="appro42", bits=8,
                                    mode="hardware"))
    x, w = _float_ops(12, 24, 8, seed=7)
    p = CiMParams.from_config(macro.config)
    got = cim_linear(x, Param(w, None), CiMContext(p))
    want = macro.matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_model_path_hardware_grads_with_3d_activations():
    """Model-zoo activations are (batch, seq, K); the STE backward must
    see a flattened x (regression: xf.T @ g crashed for rank-3 x)."""
    from repro.models.common import CiMContext, CiMParams, Param, cim_linear

    p = CiMParams(mode="hardware", family="appro42", bits=8)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 4))

    def loss(xv, wv):
        return jnp.sum(cim_linear(xv, Param(wv, None), CiMContext(p)) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert gx.shape == x.shape and gw.shape == w.shape
    assert bool(jnp.isfinite(gx).all()) and bool(jnp.isfinite(gw).all())


def test_model_path_hardware_has_ste_gradients():
    from repro.models.common import CiMContext, CiMParams, Param, cim_linear

    p = CiMParams(mode="hardware", family="log_our", bits=8)
    x, w = _float_ops(6, 16, 4, seed=8)

    def loss(xv, wv):
        return jnp.sum(cim_linear(xv, Param(wv, None), CiMContext(p)) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert bool(jnp.isfinite(gx).all()) and bool(jnp.isfinite(gw).all())
    assert float(jnp.abs(gx).max()) > 0 and float(jnp.abs(gw).max()) > 0


def test_lut_cache_first_touched_under_trace_does_not_leak():
    """Regression: _signed_lut_flat must cache numpy, not a jnp array —
    a jnp constant created during tracing is a tracer, and caching it
    leaked it out of the trace (UnexpectedTracerError when a scanned
    model layer was the first hardware-mode caller)."""
    from repro.core import approx_gemm

    approx_gemm._signed_lut_flat.cache_clear()
    gp = GemmParams(family="appro42", bits=8, mode="hardware")

    @jax.jit
    def first_touch_inside_trace(x, w):
        return cim_matmul(x, w, gp)

    x, w = _float_ops(4, 16, 4, seed=9)
    inside = first_touch_inside_trace(x, w)
    outside = cim_matmul(x, w, gp)       # reuses the cached table
    np.testing.assert_allclose(np.asarray(inside), np.asarray(outside),
                               rtol=1e-6, atol=1e-6)


# -------------------------------------------------- executable cache ----


def test_executable_cache_no_retrace_on_reuse():
    """Same GemmParams + shape + dtype reuses a cached executable: the
    trace probe must stay flat over repeated eager calls."""
    gp = GemmParams(family="appro42", bits=8, mode="hardware", mu=0.001)
    x, w = _float_ops(24, 32, 16, seed=11)
    cim_matmul(x, w, gp)                       # build + compile
    t0 = trace_count()
    for _ in range(4):
        cim_matmul(x, w, gp)
    assert trace_count() == t0, "cached eager calls retraced"
    # model frontend shares the cache machinery
    model_matmul(x, w, gp)
    t0 = trace_count()
    for _ in range(4):
        model_matmul(x, w, gp)
    assert trace_count() == t0


def test_executable_cache_semantics_match_uncached():
    gp = GemmParams(family="log_our", bits=8, mode="surrogate",
                    mu=-0.01, c0=120.0, c1=2e-4)
    x, w = _float_ops(12, 40, 8, seed=12)
    key = jax.random.PRNGKey(5)
    a = cim_matmul(x, w, gp, key)
    b = cim_matmul(x, w, gp, key, cached=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
    am = model_matmul(x, w, gp, key)
    bm = model_matmul(x, w, gp, key, cached=False)
    np.testing.assert_allclose(np.asarray(am), np.asarray(bm),
                               rtol=1e-5, atol=1e-5)


def test_executable_cache_misses_on_bucket_dtype_params():
    """Different shape-bucket / dtype / GemmParams miss correctly (new
    entries); same-bucket different shapes share one executable."""
    gp = GemmParams(family="exact", bits=8, mode="exact")
    x, w = _float_ops(16, 32, 16, seed=13)
    cim_matmul(x, w, gp)
    n0 = approx_gemm.executable_cache_size()
    # same bucket (m=16 vs m=12 both bucket to 16): no new entry
    cim_matmul(x[:12], w, gp)
    assert approx_gemm.executable_cache_size() == n0
    # new shape bucket
    x2, w2 = _float_ops(200, 32, 16, seed=13)
    cim_matmul(x2, w2, gp)
    assert approx_gemm.executable_cache_size() == n0 + 1
    # new dtype
    cim_matmul(x.astype(jnp.bfloat16), w, gp)
    assert approx_gemm.executable_cache_size() == n0 + 2
    # new params
    cim_matmul(x, w, GemmParams(family="exact", bits=8, mode="exact",
                                mu=0.5))
    assert approx_gemm.executable_cache_size() == n0 + 3


def test_executable_cache_key_distinguishes_backend():
    """Backend is part of the executable key (a TPU plan must never be
    served to a CPU call)."""
    gp = GemmParams(family="log_our", bits=8, mode="surrogate")
    plan_cpu = plan_gemm("log_our", "surrogate", 8, 16, 16, 16,
                         backend="cpu", spec=gp.spec)
    plan_tpu = plan_gemm("log_our", "surrogate", 8, 16, 16, 16,
                         backend="tpu", spec=gp.spec)
    x, w = _float_ops(16, 16, 16, seed=14)
    k_cpu = approx_gemm._exec_key("cim", gp, plan_cpu, False, "normal",
                                  True, x, w, 16, 16, 16)
    k_tpu = approx_gemm._exec_key("cim", gp, plan_tpu, False, "normal",
                                  True, x, w, 16, 16, 16)
    assert k_cpu != k_tpu


def test_cached_path_grads_match_uncached():
    gp = GemmParams(family="appro42", bits=8, mode="hardware")
    x, w = _float_ops(8, 24, 8, seed=15)

    def loss_cached(xv, wv):
        return jnp.sum(cim_matmul(xv, wv, gp) ** 2)

    def loss_uncached(xv, wv):
        return jnp.sum(cim_matmul(xv, wv, gp, cached=False) ** 2)

    gc = jax.grad(loss_cached, argnums=(0, 1))(x, w)
    gu = jax.grad(loss_uncached, argnums=(0, 1))(x, w)
    for a, b in zip(gc, gu):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- autotune ----


def test_autotune_sweep_persists_and_caches(tmp_path):
    cache = os.path.join(tmp_path, "tune.json")
    calls = []

    def fake_measure(block):
        calls.append(block)
        bm, bk, bn = block
        return abs(bm - 32) + abs(bk - 64) + abs(bn - 128) + 1.0

    autotune.clear_memory_cache()
    best = autotune.best_block("pallas_lut_gather", 8, 512, 512, 512,
                               backend="tpu", measure=fake_measure,
                               cache_file=cache)
    assert best == (32, 64, 128)
    assert len(calls) == len(
        autotune.candidate_blocks("pallas_lut_gather", 512, 512, 512))
    assert os.path.exists(cache)

    # second resolve: served from disk, measure never invoked
    autotune.clear_memory_cache()
    calls.clear()
    again = autotune.best_block("pallas_lut_gather", 8, 512, 512, 512,
                                backend="tpu", measure=fake_measure,
                                cache_file=cache)
    assert again == best and not calls


def test_autotune_off_tpu_returns_clipped_heuristic(tmp_path):
    autotune.clear_memory_cache()
    blk = autotune.best_block("pallas_log", 8, 16, 16, 16, backend="cpu",
                              cache_file=os.path.join(tmp_path, "t.json"))
    bm, bk, bn = blk
    assert bm <= 16 and bk <= 16 and bn <= 16
    assert min(blk) >= 8
    # off-TPU heuristics must not pollute the disk cache
    assert not os.path.exists(os.path.join(tmp_path, "t.json"))


def test_autotune_rejecting_measure_falls_back(tmp_path):
    def oom(block):
        raise RuntimeError("RESOURCE_EXHAUSTED: VMEM")

    autotune.clear_memory_cache()
    blk = autotune.best_block("pallas_log", 8, 64, 64, 64, backend="tpu",
                              measure=oom,
                              cache_file=os.path.join(tmp_path, "t.json"))
    assert blk == autotune.heuristic_block("pallas_log", 64, 64, 64)


@pytest.mark.parametrize("garbage", [
    "{not json",                                  # truncated / corrupt
    "[1, 2, 3]",                                  # wrong top-level type
    '{"k": 5}',                                   # wrong row type
    '{"k": [1, 2]}',                              # wrong row arity
    '{"k": ["a", "b", "c"]}',                     # wrong element type
])
def test_autotune_corrupt_cache_is_ignored_and_rewritten(tmp_path, garbage):
    cache = os.path.join(tmp_path, "tune.json")
    with open(cache, "w") as fh:
        fh.write(garbage)

    autotune.clear_memory_cache()
    best = autotune.best_block("pallas_log", 8, 64, 64, 64, backend="tpu",
                               measure=lambda b: float(sum(b)),
                               cache_file=cache)
    assert best in autotune.candidate_blocks("pallas_log", 64, 64, 64)
    # the sweep rewrote the file as valid JSON holding the winner
    with open(cache) as fh:
        disk = json.load(fh)
    assert list(disk.values()) == [list(best)]


def test_autotune_env_override_respected(tmp_path, monkeypatch):
    cache = os.path.join(tmp_path, "envtune.json")
    monkeypatch.setenv("OPENACM_AUTOTUNE_CACHE", cache)
    assert autotune.cache_path() == cache
    autotune.clear_memory_cache()
    autotune.best_block("pallas_lut_nibble", 8, 64, 64, 64, backend="tpu",
                        measure=lambda b: float(sum(b)))
    assert os.path.exists(cache)
    # and the override is where a second resolve reads from
    autotune.clear_memory_cache()
    calls = []
    autotune.best_block("pallas_lut_nibble", 8, 64, 64, 64, backend="tpu",
                        measure=lambda b: calls.append(b) or 1.0)
    assert not calls, "disk row under OPENACM_AUTOTUNE_CACHE was ignored"


def test_autotune_off_tpu_heuristic_never_writes_disk(tmp_path, monkeypatch):
    cache = os.path.join(tmp_path, "never.json")
    monkeypatch.setenv("OPENACM_AUTOTUNE_CACHE", cache)
    autotune.clear_memory_cache()
    for kernel in ("pallas_lut_gather", "pallas_lut_nibble", "pallas_log"):
        autotune.best_block(kernel, 8, 128, 128, 128, backend="cpu")
    assert not os.path.exists(cache)
