"""Kernel registry / dispatcher coverage (DESIGN.md §8).

Every (family, mode) pair must route to a registered kernel whose
output matches the kernels/ref.py oracle within the family's documented
error bound: bit-for-bit for the integer paths, fp32-allclose for the
exact/surrogate deterministic terms, and moment-level for the
stochastic surrogate (covered separately in test_error_model.py).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CiMConfig, compile_macro
from repro.core import autotune
from repro.core.approx_gemm import (FAMILIES, MODES, GemmParams,
                                    cim_matmul, plan_gemm, run_int_kernel,
                                    select_kernel, registered_kernels)
from repro.core.quantization import dequantize, quant_scale, quantize
from repro.kernels import ref

ALL_PAIRS = [(f, m) for f in FAMILIES for m in MODES]


def _float_ops(m, k, n, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (m, k)), jax.random.normal(kw, (k, n)))


def _int_ops(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-127, 128, (m, k), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, (k, n), dtype=np.int8))
    return xq, wq


# ------------------------------------------------------------- routing ----


@pytest.mark.parametrize("family,mode", ALL_PAIRS)
def test_every_pair_routes_to_a_kernel(family, mode):
    entry = select_kernel(family, mode, bits=8)
    assert entry.supports(family, mode, 8, jax.default_backend())


@pytest.mark.parametrize("family,mode", ALL_PAIRS)
def test_macro_matmul_executes_every_pair(family, mode):
    macro = compile_macro(CiMConfig(family=family, bits=8, mode=mode))
    x, w = _float_ops(9, 33, 7)
    out = macro.matmul(x, w, key=jax.random.PRNGKey(3))
    assert out.shape == (9, 7) and bool(jnp.isfinite(out).all())


def test_surrogate_routes_to_fused_kernel_on_tpu_only():
    cpu = select_kernel("log_our", "surrogate", 8, backend="cpu")
    tpu = select_kernel("log_our", "surrogate", 8, backend="tpu")
    assert cpu.name == "xla_surrogate"
    assert tpu.name == "pallas_fused_surrogate"


def test_hardware_mode_prefers_arithmetic_kernel_for_log_families():
    assert select_kernel("mitchell", "hardware", 8).name == "pallas_log"
    assert select_kernel("log_our", "hardware", 8).name == "pallas_log"
    assert select_kernel("appro42", "hardware", 8).name == "pallas_lut_gather"
    assert select_kernel("exact", "hardware", 8).name == "pallas_lut_gather"


def test_unroutable_request_raises_with_inventory():
    with pytest.raises(ValueError, match="no kernel"):
        # no hardware kernel covers a 20-bit compressor-tree family
        select_kernel("appro42", "hardware", bits=20)
    with pytest.raises(ValueError, match="not in"):
        select_kernel("exact", "warp_drive")


def test_registry_entries_document_oracles():
    for e in registered_kernels():
        assert e.oracle, f"kernel {e.name} lacks an oracle reference"
        assert e.bound in ("bit", "fp32", "stochastic")


# ------------------------------------------------- oracle equivalence ----


@pytest.mark.parametrize("family", ["exact", "appro42"])
def test_hardware_lut_kernel_bit_matches_oracle(family):
    xq, wq = _int_ops(17, 40, 9, seed=1)
    gp = GemmParams(family=family, bits=8, mode="hardware")
    plan = plan_gemm(family, "hardware", 8, 17, 40, 9)
    got = run_int_kernel(plan, xq, wq, gp)
    from repro.core.luts import signed_product_lut

    lut = jnp.asarray(signed_product_lut(gp.spec).ravel())
    want = ref.lut_matmul_ref(xq, wq, lut)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("family", ["mitchell", "log_our"])
def test_hardware_log_kernel_bit_matches_oracle(family):
    xq, wq = _int_ops(17, 40, 9, seed=2)
    gp = GemmParams(family=family, bits=8, mode="hardware")
    plan = plan_gemm(family, "hardware", 8, 17, 40, 9)
    got = run_int_kernel(plan, xq, wq, gp)
    want = ref.mitchell_matmul_ref(xq, wq,
                                   compensated=(family == "log_our"))
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("family", FAMILIES)
def test_hardware_mode_equals_bit_exact_mode(family):
    """The Pallas kernels and the jnp LUT oracle implement the same
    integer semantics (quantization clips to [-127, 127], so the
    sign-magnitude -128 edge case never arises)."""
    macro = compile_macro(CiMConfig(family=family, bits=8))
    x, w = _float_ops(13, 29, 11, seed=4)
    be = macro.matmul(x, w, mode="bit_exact")
    hw = macro.matmul(x, w, mode="hardware")
    np.testing.assert_allclose(np.asarray(be), np.asarray(hw),
                               rtol=1e-6, atol=1e-6)


def test_exact_mode_is_quantize_dequantize_dot():
    macro = compile_macro(CiMConfig(family="exact", bits=8, mode="exact"))
    x, w = _float_ops(8, 32, 4, seed=5)
    got = macro.matmul(x, w)
    sx = quant_scale(x, 8)
    sw = quant_scale(w, 8, axis=0)
    want = dequantize(quantize(x, sx, 8), sx) @ dequantize(
        quantize(w, sw, 8), sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_surrogate_without_key_is_deterministic_bias_term():
    macro = compile_macro(CiMConfig(family="log_our", bits=8))
    x, w = _float_ops(8, 64, 8, seed=6)
    a = macro.matmul(x, w)                 # key=None: no noise drawn
    b = macro.matmul(x, w)
    assert (np.asarray(a) == np.asarray(b)).all()
    gp = macro.gemm_params()
    exact = macro.matmul(x, w, mode="exact")
    np.testing.assert_allclose(np.asarray(a),
                               (1.0 + gp.mu) * np.asarray(exact),
                               rtol=3e-5, atol=3e-5)


def test_model_path_hardware_matches_macro_path():
    """cim_linear (model frontend) and CiMMacro.matmul (macro frontend)
    execute the same routed kernel for hardware mode."""
    from repro.models.common import CiMContext, CiMParams, Param, cim_linear

    macro = compile_macro(CiMConfig(family="appro42", bits=8,
                                    mode="hardware"))
    x, w = _float_ops(12, 24, 8, seed=7)
    p = CiMParams.from_config(macro.config)
    got = cim_linear(x, Param(w, None), CiMContext(p))
    want = macro.matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_model_path_hardware_grads_with_3d_activations():
    """Model-zoo activations are (batch, seq, K); the STE backward must
    see a flattened x (regression: xf.T @ g crashed for rank-3 x)."""
    from repro.models.common import CiMContext, CiMParams, Param, cim_linear

    p = CiMParams(mode="hardware", family="appro42", bits=8)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 4))

    def loss(xv, wv):
        return jnp.sum(cim_linear(xv, Param(wv, None), CiMContext(p)) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert gx.shape == x.shape and gw.shape == w.shape
    assert bool(jnp.isfinite(gx).all()) and bool(jnp.isfinite(gw).all())


def test_model_path_hardware_has_ste_gradients():
    from repro.models.common import CiMContext, CiMParams, Param, cim_linear

    p = CiMParams(mode="hardware", family="log_our", bits=8)
    x, w = _float_ops(6, 16, 4, seed=8)

    def loss(xv, wv):
        return jnp.sum(cim_linear(xv, Param(wv, None), CiMContext(p)) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert bool(jnp.isfinite(gx).all()) and bool(jnp.isfinite(gw).all())
    assert float(jnp.abs(gx).max()) > 0 and float(jnp.abs(gw).max()) > 0


def test_lut_cache_first_touched_under_trace_does_not_leak():
    """Regression: _signed_lut_flat must cache numpy, not a jnp array —
    a jnp constant created during tracing is a tracer, and caching it
    leaked it out of the trace (UnexpectedTracerError when a scanned
    model layer was the first hardware-mode caller)."""
    from repro.core import approx_gemm

    approx_gemm._signed_lut_flat.cache_clear()
    gp = GemmParams(family="appro42", bits=8, mode="hardware")

    @jax.jit
    def first_touch_inside_trace(x, w):
        return cim_matmul(x, w, gp)

    x, w = _float_ops(4, 16, 4, seed=9)
    inside = first_touch_inside_trace(x, w)
    outside = cim_matmul(x, w, gp)       # reuses the cached table
    np.testing.assert_allclose(np.asarray(inside), np.asarray(outside),
                               rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------- autotune ----


def test_autotune_sweep_persists_and_caches(tmp_path):
    cache = os.path.join(tmp_path, "tune.json")
    calls = []

    def fake_measure(block):
        calls.append(block)
        bm, bk, bn = block
        return abs(bm - 32) + abs(bk - 64) + abs(bn - 128) + 1.0

    autotune.clear_memory_cache()
    best = autotune.best_block("pallas_lut_gather", 8, 512, 512, 512,
                               backend="tpu", measure=fake_measure,
                               cache_file=cache)
    assert best == (32, 64, 128)
    assert len(calls) == len(
        autotune.candidate_blocks("pallas_lut_gather", 512, 512, 512))
    assert os.path.exists(cache)

    # second resolve: served from disk, measure never invoked
    autotune.clear_memory_cache()
    calls.clear()
    again = autotune.best_block("pallas_lut_gather", 8, 512, 512, 512,
                                backend="tpu", measure=fake_measure,
                                cache_file=cache)
    assert again == best and not calls


def test_autotune_off_tpu_returns_clipped_heuristic(tmp_path):
    autotune.clear_memory_cache()
    blk = autotune.best_block("pallas_log", 8, 16, 16, 16, backend="cpu",
                              cache_file=os.path.join(tmp_path, "t.json"))
    bm, bk, bn = blk
    assert bm <= 16 and bk <= 16 and bn <= 16
    assert min(blk) >= 8
    # off-TPU heuristics must not pollute the disk cache
    assert not os.path.exists(os.path.join(tmp_path, "t.json"))


def test_autotune_rejecting_measure_falls_back(tmp_path):
    def oom(block):
        raise RuntimeError("RESOURCE_EXHAUSTED: VMEM")

    autotune.clear_memory_cache()
    blk = autotune.best_block("pallas_log", 8, 64, 64, 64, backend="tpu",
                              measure=oom,
                              cache_file=os.path.join(tmp_path, "t.json"))
    assert blk == autotune.heuristic_block("pallas_log", 64, 64, 64)
