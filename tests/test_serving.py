"""Continuous-batching serving engine (DESIGN.md §10).

Three layers of coverage:

  * scheduler logic against a fake lane backend (fast, no jax compiles):
    property tests over randomized arrival traces — no slot leak, no
    starvation, eviction frees capacity, token budget respected — plus
    static-vs-continuous admission semantics;
  * the ragged-prefill model fix: per-sequence positions/valid masks for
    left/right-padded prompts (pad tokens never attended);
  * the real LM lanes: engine output bit-identical to the lockstep
    prefill/decode baseline for a same-arrival batch, zero steady-state
    retraces after pre-warm across tier switches and occupancy changes,
    and staggered arrivals reproducing solo-request generations.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import LM
from repro.serving import (Request, ServingEngine, SimClock,
                           build_engine, build_tiers, poisson_workload)
from repro.serving.engine import LMLaneBackend
from repro.serving.tiers import AccuracyTier, TierRouter

ARCH = "qwen3-1.7b"


# ---------------------------------------------------------------------------
# fake backend: pure scheduler exercises
# ---------------------------------------------------------------------------


class FakeLane:
    """Backend double: token = running counter, no model, no jax."""

    def __init__(self, n_slots, max_len=10_000):
        self.n_slots, self.max_len = n_slots, max_len
        self.max_group = n_slots
        self._n = 0
        self.slot_tok = np.zeros(n_slots, np.int64)
        self.admitted = 0

    def warmup(self):
        return 0

    def admit(self, prompts, slots):
        out = []
        for _, s in zip(prompts, slots):
            self._n += 1
            self.slot_tok[s] = self._n
            out.append(self._n)
        self.admitted += len(out)
        return np.asarray(out)

    def decode_round(self):
        self.slot_tok = self.slot_tok + 1
        return self.slot_tok.copy()


class FakeSpecLane(FakeLane):
    """Spec-decode backend double: a seeded accept/reject pattern over
    counter tokens.  Honors the `spec_round` protocol the engine
    schedules against — per live slot and sub-round emit `a`
    consecutive counter tokens with 1 <= a <= min(k+1, remaining),
    decrementing the budget across the call's `rounds` sub-rounds the
    way the real backend does on device; idle rows (remaining == 0)
    ride along and emit nothing.  rounds=1 returns the legacy
    single-round (B, k+1)/(B,) shapes so the engine's normalization
    path stays covered."""

    def __init__(self, n_slots, k=4, seed=0, rounds=1, max_len=10_000):
        super().__init__(n_slots, max_len)
        self.k = int(k)
        self.rounds = int(rounds)
        self.rng = np.random.default_rng(seed)

    def spec_round(self, remaining, eos):
        remaining = np.asarray(remaining, np.int64).copy()
        toks = np.zeros((self.n_slots, self.rounds, self.k + 1), np.int64)
        counts = np.zeros((self.n_slots, self.rounds), np.int64)
        for r in range(self.rounds):
            for s in range(self.n_slots):
                if remaining[s] <= 0:
                    continue
                a = min(int(self.rng.integers(1, self.k + 2)),
                        int(remaining[s]))
                toks[s, r, :a] = self.slot_tok[s] + 1 + np.arange(a)
                counts[s, r] = a
                self.slot_tok[s] += a
                remaining[s] -= a
        if self.rounds == 1:
            return toks[:, 0], counts[:, 0]
        return toks, counts


def check_spec_trace(spec, n_slots, k, accept_seed, continuous=True,
                     rounds=1):
    """Spec-decode scheduler oracle (hypothesis drives it in
    test_serving_properties.py): whatever the seeded accept/reject
    trace does round to round — including multi-round calls that
    finish a request mid-call — the engine must keep FIFO admission,
    slot hygiene and exact per-request token budgets, and every
    request's final sequence must be the contiguous counter run that
    started at its admit token — no token lost, duplicated or
    misattributed across variable-length emissions."""
    tiers = _fake_tiers(("a",))
    lane = FakeSpecLane(n_slots, k=k, seed=accept_seed, rounds=rounds)
    eng = ServingEngine({"a": lane}, TierRouter(tiers),
                        continuous=continuous, check_invariants=True)
    t = 0.0
    reqs = []
    for i, (gap, plen, max_new) in enumerate(spec):
        t += gap
        reqs.append(_req(i, tier="a", plen=plen, max_new=max_new,
                         arrival=t))
    res = eng.run(reqs, clock=SimClock())
    assert len(res) == len(reqs)                       # no starvation
    for r in reqs:
        rr = res[r.rid]
        assert rr.done
        assert len(rr.tokens) == r.max_new             # budget exact
        first = rr.tokens[0]
        assert rr.tokens == list(range(first, first + r.max_new)), \
            f"rid {r.rid}: sequence not preserved across spec rounds"
    admits = [res[r.rid].t_admit
              for r in sorted(reqs, key=lambda r: (r.arrival, r.rid))]
    assert admits == sorted(admits)                    # FIFO admission
    assert eng.active_tokens == 0
    assert sorted(eng.lanes["a"].free) == list(range(n_slots))


@pytest.mark.parametrize("seed", range(6))
def test_spec_scheduler_seeded_traces(seed):
    """Seeded spec-trace sweep (runs even without hypothesis)."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(1, 26))
    spec = [(float(rng.uniform(0, 0.5)), int(rng.integers(1, 9)),
             int(rng.integers(1, 10))) for _ in range(n)]
    check_spec_trace(spec, n_slots=int(rng.integers(1, 4)),
                     k=int(rng.integers(1, 5)), accept_seed=seed,
                     continuous=bool(seed % 2),
                     rounds=int(rng.integers(1, 5)))


def test_spec_trace_oracle_has_teeth():
    """The oracle actually catches a scheduler that loses a token."""

    class LossyLane(FakeSpecLane):
        def spec_round(self, remaining, eos):
            toks, counts = super().spec_round(remaining, eos)
            self.slot_tok += 1           # skip a counter value: a lost
            return toks, counts          # token on the NEXT round

    tiers = _fake_tiers(("a",))
    eng = ServingEngine({"a": LossyLane(1, k=2, seed=0)},
                        TierRouter(tiers), check_invariants=True)
    with pytest.raises(AssertionError):
        res = eng.run([_req(0, tier="a", max_new=8)], clock=SimClock())
        first = res[0].tokens[0]
        assert res[0].tokens == list(range(first, first + 8))


def _fake_tiers(names=("a", "b")):
    return [AccuracyTier(n, None, 0.001 * i, 1.0 + i)
            for i, n in enumerate(names)]


def _fake_engine(n_slots=3, names=("a", "b"), **kw):
    tiers = _fake_tiers(names)
    lanes = {t.name: FakeLane(n_slots) for t in tiers}
    return ServingEngine(lanes, TierRouter(tiers),
                         check_invariants=True, **kw), lanes


def _req(rid, tier="a", plen=4, max_new=3, arrival=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int64),
                   max_new=max_new, tier=tier, arrival=arrival)


def test_scheduler_basic_complete():
    eng, lanes = _fake_engine()
    reqs = [_req(i, tier="ab"[i % 2], max_new=1 + i % 4,
                 arrival=0.01 * i) for i in range(10)]
    res = eng.run(reqs, clock=SimClock())
    assert len(res) == 10
    for r in reqs:
        assert res[r.rid].done
        assert len(res[r.rid].tokens) == r.max_new
    for lane in eng.lanes.values():          # eviction freed every slot
        assert not lane.running and not lane.queue
        assert sorted(lane.free) == list(range(lane.backend.n_slots))
    assert eng.active_tokens == 0


def test_scheduler_static_waits_for_full_batch():
    eng, lanes = _fake_engine(n_slots=2, names=("a",), continuous=False)
    reqs = [_req(i, max_new=2, arrival=0.1 * i) for i in range(4)]
    res = eng.run(reqs, clock=SimClock())
    assert all(r.done for r in res.values())
    # static admission: batches of exactly n_slots (full drains between)
    assert lanes["a"].admitted == 4
    assert eng.peak_running <= 2


def test_scheduler_token_budget_blocks_head():
    eng, _ = _fake_engine(n_slots=3, names=("a",), token_budget=12)
    reqs = [_req(i, plen=4, max_new=2, arrival=0.0) for i in range(5)]
    res = eng.run(reqs, clock=SimClock())        # cost 6 each: 2 at a time
    assert all(r.done for r in res.values())
    assert eng.peak_running <= 2                 # 12 // 6


def test_submit_rejects_live_duplicate_rid():
    eng, _ = _fake_engine(n_slots=2, names=("a",))
    eng.submit(_req(0, max_new=3))
    with pytest.raises(ValueError):
        eng.submit(_req(0, max_new=3))       # still queued/running
    while not eng.results[0].done:
        eng.step()
    eng.submit(_req(0, max_new=2))           # done: rid reuse is fine
    res = eng.run([], clock=SimClock())
    assert not res                           # run() returns its own batch
    assert eng.results[0].done


def test_submit_rejects_oversized():
    eng, _ = _fake_engine(n_slots=2, names=("a",), token_budget=8)
    with pytest.raises(ValueError):
        eng.submit(_req(0, plen=6, max_new=6))   # cost 12 > budget
    tiers = _fake_tiers(("a",))
    lane = FakeLane(2, max_len=8)
    eng2 = ServingEngine({"a": lane}, TierRouter(tiers))
    with pytest.raises(ValueError):
        eng2.submit(_req(1, plen=6, max_new=6))  # cost 12 > max_len


def check_random_trace(spec, n_slots, continuous):
    """Shared property oracle (also driven by hypothesis in
    test_serving_properties.py): no slot leak, no starvation, budget
    respected, eviction frees capacity — engine invariants are asserted
    every tick (check_invariants=True) and the end state is drained."""
    tiers = _fake_tiers(("a", "b"))
    lanes = {t.name: FakeLane(n_slots) for t in tiers}
    budget = 2 * n_slots * 14                     # max cost = 8 + 6
    eng = ServingEngine(lanes, TierRouter(tiers), continuous=continuous,
                        token_budget=budget, check_invariants=True)
    t = 0.0
    reqs = []
    for i, (gap, plen, max_new, tier_i) in enumerate(spec):
        t += gap
        reqs.append(_req(i, tier="ab"[tier_i], plen=plen,
                         max_new=max_new, arrival=t))
    res = eng.run(reqs, clock=SimClock())
    assert len(res) == len(reqs)                       # no starvation
    for r in reqs:
        assert res[r.rid].done
        assert len(res[r.rid].tokens) == r.max_new
    assert eng.active_tokens == 0
    total_slots = sum(len(l.free) for l in eng.lanes.values())
    assert total_slots == 2 * n_slots                  # no slot leak
    assert eng.peak_running <= 2 * n_slots


@pytest.mark.parametrize("seed", range(8))
def test_scheduler_random_traces_seeded(seed):
    """Seeded randomized-trace sweep (runs even without hypothesis; the
    hypothesis-driven search lives in test_serving_properties.py)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 26))
    spec = [(float(rng.uniform(0, 0.5)), int(rng.integers(1, 9)),
             int(rng.integers(1, 7)), int(rng.integers(0, 2)))
            for _ in range(n)]
    check_random_trace(spec, n_slots=int(rng.integers(1, 4)),
                       continuous=bool(seed % 2))


# ---------------------------------------------------------------------------
# ragged prefill: per-sequence positions / pad-validity masks
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_config(ARCH, smoke=True)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.PRNGKey(0))


def test_ragged_prefill_matches_solo(smoke_lm):
    """Right-padded ragged batch: each sequence's last-token logits
    match its solo (unpadded) prefill — pad tokens are invisible."""
    cfg, lm, params = smoke_lm
    rng = np.random.default_rng(0)
    b, s = 3, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
    lens = jnp.asarray([12, 7, 4], jnp.int32)
    lp, _ = lm.prefill(params, {"tokens": toks, "lengths": lens,
                                "max_len": 16})
    for i in range(b):
        li = int(lens[i])
        solo, _ = lm.prefill(params, {"tokens": toks[i:i + 1, :li],
                                      "max_len": 16})
        np.testing.assert_allclose(
            np.asarray(lp[i, -1], np.float32),
            np.asarray(solo[0, -1], np.float32), rtol=5e-2, atol=5e-2,
            err_msg=f"ragged row {i} (len {li}) diverged from solo")


def test_left_pad_matches_right_pad(smoke_lm):
    cfg, lm, params = smoke_lm
    rng = np.random.default_rng(1)
    b, s = 3, 10
    toks = np.asarray(rng.integers(0, cfg.vocab, (b, s)))
    lens = np.asarray([10, 6, 3], np.int32)
    lp_r, _ = lm.prefill(params, {"tokens": jnp.asarray(toks),
                                  "lengths": jnp.asarray(lens),
                                  "max_len": 12})
    toksl = np.zeros_like(toks)
    for i in range(b):
        toksl[i, s - lens[i]:] = toks[i, :lens[i]]
    lp_l, caches_l = lm.prefill(params, {"tokens": jnp.asarray(toksl),
                                         "lengths": jnp.asarray(lens),
                                         "pad": "left", "max_len": 12})
    np.testing.assert_allclose(np.asarray(lp_l[:, -1], np.float32),
                               np.asarray(lp_r[:, -1], np.float32),
                               rtol=5e-2, atol=5e-2)
    # left padding is scoring-only: no decodable caches come back (pad
    # K/V would sit at the slot head, invisible to the fill-level mask)
    assert caches_l is None


def test_ragged_prefill_pad_tokens_masked(smoke_lm):
    """Pad CONTENT must not leak: scrambling the pad region changes
    nothing about any real token's logits."""
    cfg, lm, params = smoke_lm
    rng = np.random.default_rng(2)
    b, s = 2, 10
    toks = np.asarray(rng.integers(0, cfg.vocab, (b, s)))
    lens = jnp.asarray([6, 4], jnp.int32)
    lp1, _ = lm.prefill(params, {"tokens": jnp.asarray(toks),
                                 "lengths": lens, "max_len": 12})
    toks2 = toks.copy()
    toks2[0, 6:] = (toks2[0, 6:] + 13) % cfg.vocab
    toks2[1, 4:] = (toks2[1, 4:] + 7) % cfg.vocab
    lp2, _ = lm.prefill(params, {"tokens": jnp.asarray(toks2),
                                 "lengths": lens, "max_len": 12})
    assert np.array_equal(np.asarray(lp1), np.asarray(lp2)), \
        "pad token content leaked into real-token logits"


def test_ragged_decode_continuation(smoke_lm):
    """Per-slot decode from a ragged prefill tracks each sequence's own
    position (the slot-pool contract)."""
    cfg, lm, params = smoke_lm
    rng = np.random.default_rng(3)
    b, s, gen = 2, 8, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
    lens = jnp.asarray([8, 5], jnp.int32)
    lp, caches = lm.prefill(params, {"tokens": toks, "lengths": lens,
                                     "max_len": 16})
    tok = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
    pos = jnp.asarray(lens)
    rag = [np.asarray(lp[:, -1], np.float32)]
    for _ in range(gen):
        lp, caches = lm.decode_step(params, caches, tok, pos)
        tok = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
        pos = pos + 1
        rag.append(np.asarray(lp[:, -1], np.float32))
    for i in range(b):
        li = int(lens[i])
        solo, c = lm.prefill(params, {"tokens": toks[i:i + 1, :li],
                                      "max_len": 16})
        tk = jnp.argmax(solo[:, -1], -1)[:, None].astype(jnp.int32)
        np.testing.assert_allclose(rag[0][i], np.asarray(
            solo[0, -1], np.float32), rtol=5e-2, atol=5e-2)
        for step in range(gen):
            solo, c = lm.decode_step(params, c, tk, jnp.int32(li + step))
            tk = jnp.argmax(solo[:, -1], -1)[:, None].astype(jnp.int32)
            np.testing.assert_allclose(
                rag[step + 1][i], np.asarray(solo[0, -1], np.float32),
                rtol=5e-2, atol=5e-2,
                err_msg=f"row {i} decode step {step} diverged")


# ---------------------------------------------------------------------------
# real LM lanes: bit-identity, pre-warm / zero-retrace, tier routing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tier_name", ["exact", "balanced"])
def test_engine_bit_identical_to_lockstep(smoke_lm, tier_name):
    """All requests arriving together == the lockstep baseline, logit
    for logit (acceptance criterion: the slot pool is a pure
    generalization, not an approximation)."""
    cfg, _, params = smoke_lm
    tier = {t.name: t for t in build_tiers()}[tier_name]
    lm = LM(dataclasses.replace(cfg, cim=tier.cim))
    rng = np.random.default_rng(4)
    b, s, gen, max_len = 2, 8, 3, 16
    toks = rng.integers(0, cfg.vocab, (b, s))

    lp, caches = lm.prefill(params, {"tokens": jnp.asarray(toks),
                                     "max_len": max_len})
    tok = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
    ref = [np.asarray(lp[:, -1], np.float32)]
    for i in range(gen - 1):
        lp, caches = lm.decode_step(params, caches, tok, jnp.int32(s + i))
        tok = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
        ref.append(np.asarray(lp[:, -1], np.float32))

    lane = LMLaneBackend(lm, params, n_slots=b, max_len=max_len,
                         prompt_buckets=(s,), group_buckets=(b,))
    eng = ServingEngine({tier.name: lane}, TierRouter([tier]),
                        record_logits=True)
    eng.warmup()
    reqs = [Request(rid=i, prompt=toks[i], max_new=gen, tier=tier.name)
            for i in range(b)]
    res = eng.run(reqs, clock=SimClock())
    assert eng.steady_retraces() == 0
    for i in range(b):
        assert len(res[i].logits) == gen
        for t in range(gen):
            assert np.array_equal(res[i].logits[t], ref[t][i]), \
                f"req {i} token {t}: engine != lockstep (tier {tier_name})"


def test_engine_prewarm_zero_steady_retraces(smoke_lm):
    """Every (tier x prompt-bucket x group-bucket) executable is built
    at warmup; serving mixed-tier Poisson traffic with occupancy churn
    never retraces the dispatch engine afterwards."""
    cfg, _, params = smoke_lm
    tiers = build_tiers(families=("exact", "appro42"))
    eng = build_engine(cfg, params, tiers=tiers, slots_per_tier=2,
                       max_len=24, prompt_buckets=(6,),
                       group_buckets=(1, 2))
    n = eng.warmup()
    assert n == len(tiers) * (1 * 2 + 1)   # (P x G) prefills + decode
    wl = poisson_workload(8, rate=500.0, vocab=cfg.vocab,
                          prompt_len=(3, 6), max_new=(1, 5),
                          tier_mix=(("exact", None, 1.0),
                                    ("balanced", None, 1.0)), seed=5)
    res = eng.run(wl)
    assert all(r.done for r in res.values())
    assert {r.tier for r in res.values()} == {"exact", "balanced"}
    assert eng.steady_retraces() == 0, \
        "tier switches / occupancy changes retraced after pre-warm"


def test_engine_staggered_matches_solo(smoke_lm):
    """A request that joins a half-busy pool mid-flight generates the
    same tokens as when served alone (CiM off: rows are independent)."""
    cfg, lm, params = smoke_lm
    float_tier = AccuracyTier("float", None, 0.0, 0.0)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, (6,)) for _ in range(3)]

    solo_tokens = []
    for p in prompts:
        lane = LMLaneBackend(lm, params, n_slots=2, max_len=24,
                             prompt_buckets=(6,), group_buckets=(1, 2))
        eng = ServingEngine({"float": lane}, TierRouter([float_tier]))
        eng.warmup()
        res = eng.run([Request(rid=0, prompt=p, max_new=5,
                               tier="float")], clock=SimClock())
        solo_tokens.append(res[0].tokens)

    lane = LMLaneBackend(lm, params, n_slots=2, max_len=24,
                         prompt_buckets=(6,), group_buckets=(1, 2))
    eng = ServingEngine({"float": lane}, TierRouter([float_tier]))
    eng.warmup()
    reqs = [Request(rid=i, prompt=prompts[i], max_new=5, tier="float",
                    arrival=0.0) for i in range(3)]     # 3 reqs, 2 slots
    res = eng.run(reqs)
    assert eng.steady_retraces() == 0
    for i in range(3):
        assert res[i].tokens == solo_tokens[i], \
            f"req {i}: pool-shared generation diverged from solo"


def test_tier_router():
    tiers = build_tiers()
    r = TierRouter(tiers)
    assert r.route(0.0).name == "exact"
    assert r.route(None).name == "exact"
    by_name = {t.name: t for t in tiers}
    # any tolerance admitting 'balanced' routes there (cheapest energy)
    assert r.route(by_name["balanced"].nmed).name == "balanced"
    assert r.route(1.0).name == "balanced"
    assert r.route(tier="economy").name == "economy"
    with pytest.raises(KeyError):
        r.route(tier="no-such-tier")
    with pytest.raises(ValueError):
        TierRouter([t for t in tiers if t.nmed > 0]).route(0.0)


def test_engine_rejects_non_attention_arch():
    cfg = get_config("recurrentgemma-9b", smoke=True)
    with pytest.raises(ValueError):
        build_engine(cfg, tiers=build_tiers(families=("exact",)))
    from repro.serving import servable_archs

    names = servable_archs()
    assert "qwen3-1.7b" in names and "recurrentgemma-9b" not in names


def test_workload_reproducible_across_processes():
    """poisson_workload must be a pure function of its seed — arrivals,
    prompt tokens, budgets and tier picks all come from one
    `np.random.default_rng(seed)` (no global or hash-seeded state), so
    a workload can be replayed exactly in another process (the
    benchmark's cross-engine comparisons and the spec-decode
    differential tests depend on it)."""
    import json
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    body = (
        "import json, sys\n"
        f"sys.path.insert(0, {src!r})\n"
        "from repro.serving import poisson_workload\n"
        "wl = poisson_workload(6, rate=50.0, vocab=97,\n"
        "                      prompt_len=(2, 5), max_new=(1, 4),\n"
        "                      tier_mix=(('exact', None, 0.5),\n"
        "                                ('balanced', None, 0.5)),\n"
        "                      seed=123)\n"
        "print(json.dumps([[r.rid, r.arrival, r.max_new, r.tier,\n"
        "                   r.prompt.tolist()] for r in wl]))\n")
    out = subprocess.run([sys.executable, "-c", body],
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    child = json.loads(out.stdout.strip().splitlines()[-1])
    wl = poisson_workload(6, rate=50.0, vocab=97, prompt_len=(2, 5),
                          max_new=(1, 4),
                          tier_mix=(("exact", None, 0.5),
                                    ("balanced", None, 0.5)), seed=123)
    here = [[r.rid, r.arrival, r.max_new, r.tier, r.prompt.tolist()]
            for r in wl]
    assert child == here, "workload drifted across processes"


def test_ragged_prefill_rejected_for_stateful_stacks():
    """Ragged prefill would silently corrupt ring-buffered / recurrent
    state — it must raise, not degrade."""
    cfg = get_config("recurrentgemma-9b", smoke=True)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(ValueError):
        lm.prefill(params, {"tokens": toks,
                            "lengths": jnp.asarray([8, 4], jnp.int32),
                            "max_len": 16})
