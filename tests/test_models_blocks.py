"""Block-level equivalences: chunked attention vs naive, mLSTM chunkwise
vs sequential, RG-LRU scan vs step, MoE dispatch vs dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _chunked_attn
from repro.models.common import OFF, unbox
from repro.models.moe import init_moe, moe_block
from repro.models.config import MoEConfig
from repro.models.rglru import init_rglru, init_rglru_cache, rglru_block
from repro.models.xlstm import (_mlstm_chunk_scan, _mlstm_step, init_mlstm,
                                init_mlstm_cache, mlstm_block)


def _naive_attn(q, k, v, causal, window=None):
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, d).astype(np.float32)
    s = np.einsum("bqkgd,btkd->bkgqt", qg, np.asarray(k, np.float32))
    s = s / np.sqrt(d)
    t = k.shape[1]
    mask = np.ones((sq, t), bool)
    if causal:
        mask &= np.arange(t)[None, :] <= np.arange(sq)[:, None]
    if window is not None:
        mask &= np.arange(t)[None, :] > np.arange(sq)[:, None] - window
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = np.einsum("bkgqt,btkd->bkgqd", p, np.asarray(v, np.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)


@pytest.mark.parametrize("causal,window,kh", [(True, None, 4), (True, None, 2),
                                              (False, None, 4),
                                              (True, 16, 1)])
def test_chunked_attention_vs_naive(causal, window, kh):
    b, s, h, d = 2, 64, 4, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kh, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kh, d))
    got = _chunked_attn(q, k, v, 16, 16, causal, window, 0, s)
    want = _naive_attn(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_chunked_attention_separate_value_dim():
    b, s, h, dk, dv = 1, 32, 2, 12, 20
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dk))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dk))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, dv))
    got = _chunked_attn(q, k, v, 8, 8, True, None, 0, s)
    assert got.shape == (b, s, h, dv)
    want = _naive_attn(q, k, jnp.pad(v, ((0, 0),) * 3 + ((0, 0),)), True)[
        ..., :dv] if dv <= dk else None
    # cross-check against a direct computation
    s_ = np.einsum("bqhd,bthd->bhqt", np.asarray(q, np.float32),
                   np.asarray(k, np.float32)) / np.sqrt(dk)
    mask = np.arange(s)[None, :] <= np.arange(s)[:, None]
    s_ = np.where(mask[None, None], s_, -1e30)
    p = np.exp(s_ - s_.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bhqt,bthd->bqhd", p, np.asarray(v, np.float32))
    np.testing.assert_allclose(np.asarray(got), o, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ mLSTM --

def _mlstm_sequential(q, k, v, li, lf):
    b, t, nh, dk = q.shape
    state = (jnp.zeros((b, nh, dk, dk)), jnp.zeros((b, nh, dk)),
             jnp.zeros((b, nh)))
    hs = []
    for i in range(t):
        h, state = _mlstm_step(q[:, i], k[:, i], v[:, i], li[:, i],
                               lf[:, i], state)
        hs.append(h)
    return jnp.stack(hs, axis=1), state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunkwise_equals_sequential(chunk):
    b, t, nh, dk = 2, 16, 2, 8
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(keys[0], (b, t, nh, dk))
    k = jax.random.normal(keys[1], (b, t, nh, dk)) * 0.5
    v = jax.random.normal(keys[2], (b, t, nh, dk))
    li = jax.random.normal(keys[3], (b, t, nh)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(keys[4], (b, t, nh)) + 1.0)
    state0 = (jnp.zeros((b, nh, dk, dk)), jnp.zeros((b, nh, dk)),
              jnp.zeros((b, nh)))
    h_c, st_c = _mlstm_chunk_scan(q, k, v, li, lf, state0, chunk)
    h_s, st_s = _mlstm_sequential(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c[1]), np.asarray(st_s[1]),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_block_prefill_then_decode_consistent():
    b, s, d, nh = 1, 12, 16, 2
    params = init_mlstm(jax.random.PRNGKey(0), d, nh)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s + 1, d),
                          dtype=jnp.bfloat16)
    full, _ = mlstm_block(params, x, n_heads=nh, chunk=4, ctx=OFF)
    cache = init_mlstm_cache(b, d, nh)
    pre, cache = mlstm_block(params, x[:, :s], n_heads=nh, chunk=4, ctx=OFF,
                             cache=cache)
    dec, _ = mlstm_block(params, x[:, s:], n_heads=nh, chunk=4, ctx=OFF,
                         cache=cache)
    np.testing.assert_allclose(np.asarray(dec[:, 0], np.float32),
                               np.asarray(full[:, s], np.float32),
                               rtol=5e-2, atol=5e-2)


# ----------------------------------------------------------------- RG-LRU --

def test_rglru_scan_equals_stepwise_decode():
    b, s, d = 2, 10, 16
    params = init_rglru(jax.random.PRNGKey(0), d, d, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d),
                          dtype=jnp.bfloat16)
    full, _ = rglru_block(params, x, ctx=OFF)
    cache = init_rglru_cache(b, d, 4)
    outs = []
    for i in range(s):
        y, cache = rglru_block(params, x[:, i:i + 1], ctx=OFF, cache=cache)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)


# -------------------------------------------------------------------- MoE --

def test_moe_matches_dense_reference_with_ample_capacity():
    d, e, k = 16, 4, 2
    moe = MoEConfig(n_routed=e, top_k=k, d_expert=32, n_shared=0,
                    capacity_factor=4.0, aux_loss_coef=0.0)
    params = init_moe(jax.random.PRNGKey(0), d, moe, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d),
                          dtype=jnp.float32)
    y, aux = moe_block(params, x, moe=moe, act="swiglu", ctx=OFF)

    # dense reference: every token through its top-k experts
    xf = np.asarray(x.reshape(-1, d), np.float32)
    router = np.asarray(params["router"].value, np.float32)
    logits = xf @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :k]
    wi = np.asarray(params["wi"].value, np.float32)
    wg = np.asarray(params["wg"].value, np.float32)
    wo = np.asarray(params["wo"].value, np.float32)
    want = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        wsel = probs[t, top[t]]
        wsel = wsel / wsel.sum()
        for j, ex in enumerate(top[t]):
            h = xf[t] @ wi[ex]
            h = h / (1 + np.exp(-h)) * (xf[t] @ wg[ex])
            want[t] += wsel[j] * (h @ wo[ex])
    np.testing.assert_allclose(np.asarray(y).reshape(-1, d), want,
                               rtol=2e-2, atol=2e-2)


def test_moe_capacity_drops_overflow():
    d, e = 8, 2
    moe = MoEConfig(n_routed=e, top_k=1, d_expert=16, capacity_factor=0.1)
    params = init_moe(jax.random.PRNGKey(0), d, moe, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, d))
    y, _ = moe_block(params, x, moe=moe, act="swiglu", ctx=OFF)
    # capacity ~3 tokens/expert -> most outputs are exactly zero
    zero_rows = (np.abs(np.asarray(y)).sum(-1) < 1e-7).mean()
    assert zero_rows > 0.7
