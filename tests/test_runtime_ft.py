"""Fault tolerance: checkpoint atomicity, crash/resume determinism,
straggler detection, optimizer correctness, data pipeline replay."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import TokenStream
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import LM
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def _trainer(tmp, steps=6, ckpt_every=3, seed=0):
    cfg = get_config("qwen3-1.7b", smoke=True)
    lm = LM(cfg)
    data = TokenStream(cfg.vocab, seq_len=32, global_batch=4, seed=seed)
    mesh = make_host_mesh()
    return Trainer(lm, adamw.AdamWConfig(lr=1e-3, state_bits=32,
                                         warmup_steps=2, total_steps=steps),
                   mesh, TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                                       ckpt_dir=tmp, seed=seed), data)


def test_train_loss_decreases(tmp_path):
    t = _trainer(str(tmp_path / "a"), steps=12)
    out = t.run()
    losses = out["losses"]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_crash_resume_replays_exactly(tmp_path):
    d1 = str(tmp_path / "crash")
    t1 = _trainer(d1, steps=8, ckpt_every=2)
    with pytest.raises(RuntimeError, match="injected failure"):
        t1.run(inject_failure_at=4)
    # fresh process-equivalent: new trainer, same dir -> resumes at step 4
    t2 = _trainer(d1, steps=8, ckpt_every=2)
    out2 = t2.run()
    # uninterrupted reference
    t3 = _trainer(str(tmp_path / "ref"), steps=8, ckpt_every=2)
    out3 = t3.run()
    got = out2["losses"]
    want = out3["losses"][-len(got):]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_checkpoint_atomic_commit(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(8.0), "n": jnp.int32(3)}
    ck.save(5, tree, blocking=True)
    # a stale tmp dir from a crashed writer must be invisible
    os.makedirs(str(tmp_path / "step_9.tmp"), exist_ok=True)
    assert ck.all_steps() == [5]
    step, restored = ck.restore_latest(jax.eval_shape(lambda: tree))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))


def test_checkpoint_gc_keeps_last_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.all_steps() == [3, 4]


def test_straggler_detection(tmp_path):
    t = _trainer(str(tmp_path / "s"), steps=10)
    out = t.run(inject_straggler_at=7)
    assert out["straggler_events"] >= 1


def test_elastic_remesh_restores(tmp_path):
    t = _trainer(str(tmp_path / "e"), steps=4, ckpt_every=2)
    t.run()
    # "lose" devices: rebuild on a fresh mesh and resume from checkpoint
    t.remesh(make_host_mesh())
    params, opt = t.init_state()
    step, params, opt = t.try_resume(params, opt)
    assert step == 4


def test_data_pipeline_deterministic_and_restorable():
    a = TokenStream(1000, 16, 4, seed=7)
    b1 = [a.next_batch() for _ in range(3)]
    st = a.state()
    b2 = a.next_batch()
    a2 = TokenStream(1000, 16, 4, seed=7)
    a2.restore(st)
    np.testing.assert_array_equal(a2.next_batch(), b2)
    fresh = TokenStream(1000, 16, 4, seed=7)
    np.testing.assert_array_equal(fresh.next_batch(), b1[0])


def test_int8_adam_tracks_fp32_adam():
    def loss(w):
        return jnp.sum((w - 3.0) ** 2)

    for bits in (32, 8):
        w = jnp.zeros(512)
        cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, state_bits=bits,
                                warmup_steps=0, total_steps=100,
                                min_lr_frac=1.0)
        st = adamw.init(w, cfg)
        for _ in range(60):
            g = jax.grad(loss)(w)
            w, st, _ = adamw.apply_updates(w, g, st, cfg)
        assert float(loss(w)) < 0.3, f"state_bits={bits} failed to converge"
