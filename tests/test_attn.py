"""Fused CiM attention coverage (DESIGN.md §13).

The attention frontend (`cim_attention`) must be **bit-identical** to
the materialized oracle surface (`attn_materialized_oracle`: the same
integer math with the (B, H, Sq, Skv) score tensor written through HBM)
on every routed kernel, across the masking universe (causal / windowed
/ ragged prefill / single-token decode) and GQA group counts; carry the
STE backward (= exact float VJP); fall back per the documented
predicates; and execute through the zero-retrace executable cache like
every other frontend.  Also pins the `_chunked_attn` q-padding fix and
the attention rows of the shared autotune disk cache.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import approx_gemm, autotune
from repro.core.approx_gemm import (ATTN_MODES, AttnParams, GemmParams,
                                    _attn_bit_safe, attn_materialized_oracle,
                                    cim_attention, plan_attn,
                                    select_attn_kernel, trace_count)
from repro.models.attention import _chunked_attn, _cim_sdpa, _use_cim_attn
from repro.models.common import CiMParams

# (family, mode, expected kernel): every attention kernel family, incl.
# both LUT layouts via the nibble predicate
HW_CASES = [
    ("exact", "exact", "pallas_attn_mxu"),
    ("exact", "hardware", "pallas_attn_nibble"),
    ("appro42", "hardware", "pallas_attn_lut"),
    ("mitchell", "hardware", "pallas_attn_log"),
    ("log_our", "hardware", "pallas_attn_log"),
    ("appro42", "bit_exact", "attn_xla"),
]

# small ragged geometry + small tiles: every test kernel runs in
# interpret mode off-TPU, so tile counts dominate the suite's runtime
B, H, KH, SQ, SKV, D = 2, 4, 2, 21, 29, 12
BLOCK = (8, 16)


def _ops(b=B, sq=SQ, skv=SKV, h=H, kh=KH, d=D, seed=0):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (b, sq, h, d))
    k = jax.random.normal(kk, (b, skv, kh, d))
    v = jax.random.normal(kv, (b, skv, kh, d))
    return q, k, v


def _full_pos(b, sq, skv):
    qpos = jnp.broadcast_to(jnp.arange(skv - sq, skv, dtype=jnp.int32),
                            (b, sq))
    kpos = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32), (b, skv))
    kval = jnp.ones((b, skv), jnp.int32)
    return qpos, kpos, kval


def _oracle(q, k, v, gp, plan, qpos, kpos, kval):
    """Frontend-layout wrapper over the kernel-layout oracle surface."""
    t = lambda a: jnp.transpose(a, (0, 2, 1, 3))  # noqa: E731
    return t(attn_materialized_oracle(t(q), t(k), t(v), gp, plan,
                                      qpos, kpos, kval))


# ------------------------------------------------------------- routing ----


@pytest.mark.parametrize("family,mode,kernel", HW_CASES)
def test_attn_routing(family, mode, kernel):
    gp = GemmParams(family=family, bits=8, mode=mode)
    assert select_attn_kernel(family, mode, 8, spec=gp.spec).name == kernel
    plan = plan_attn(family, mode, 8, B, H, KH, SQ, SKV, D, AttnParams(),
                     spec=gp.spec)
    assert plan.entry.name == kernel
    assert plan.attn == AttnParams()


def test_attn_mode_and_geometry_validation():
    gp = GemmParams(family="appro42", bits=8, mode="hardware")
    q, k, v = _ops()
    with pytest.raises(ValueError):
        plan_attn("appro42", "surrogate", 8, B, H, KH, SQ, SKV, D)
    with pytest.raises(ValueError):      # H % KH != 0
        cim_attention(q[:, :, :3], k, v, gp)
    with pytest.raises(ValueError):      # per-token scales: linear-only
        cim_attention(q, k, v, GemmParams(family="appro42", bits=8,
                                          mode="hardware", per_token=True))
    assert "surrogate" not in ATTN_MODES


def test_attn_predicates_reject_unsafe_geometry():
    # 12-bit products overflow the f32-exact window on the MXU path but
    # fit the int32 paths
    assert not _attn_bit_safe(12, "mxu", 128, 128)
    assert _attn_bit_safe(8, "mxu", 128, 128)
    assert _attn_bit_safe(12, "log", 128, 128)
    # no registered kernel survives 16-bit operands
    with pytest.raises(ValueError):
        plan_attn("appro42", "hardware", 16, B, H, KH, SQ, SKV, D)


# -------------------------------------------- bit-identity vs oracle ----


@pytest.mark.parametrize("family,mode,kernel", HW_CASES)
@pytest.mark.parametrize("variant", ["causal", "window", "ragged",
                                     "decode"])
def test_attn_bit_identity_vs_materialized_oracle(family, mode, kernel,
                                                  variant):
    gp = GemmParams(family=family, bits=8, mode=mode)
    causal, window = True, None
    if variant == "decode":
        q, k, v = _ops(sq=1, seed=3)
        qpos, kpos, kval = _full_pos(B, 1, SKV)
        kval = (kpos < jnp.asarray([[23], [29]])).astype(jnp.int32)
    else:
        q, k, v = _ops(seed=3)
        qpos, kpos, kval = _full_pos(B, SQ, SKV)
        if variant == "window":
            window = 5
        elif variant == "ragged":
            kval = (kpos < jnp.asarray([[17], [29]])).astype(jnp.int32)
    plan = plan_attn(family, mode, 8, *q.shape[:1], H, KH, q.shape[1],
                     SKV, D, AttnParams(causal=causal, window=window),
                     block=BLOCK, spec=gp.spec)
    assert plan.entry.name == kernel
    got = cim_attention(q, k, v, gp, causal=causal, window=window,
                        q_positions=qpos, kv_positions=kpos,
                        kv_valid=kval, block=BLOCK)
    want = _oracle(q, k, v, gp, plan, qpos, kpos, kval)
    assert got.shape == q.shape
    assert np.isfinite(np.asarray(got)).all()
    assert (np.asarray(got) == np.asarray(want)).all(), \
        f"{kernel} diverged from the materialized oracle ({variant})"


@pytest.mark.parametrize("kh", [1, 2, 4])
def test_attn_bit_identity_across_gqa_groups(kh):
    gp = GemmParams(family="appro42", bits=8, mode="hardware")
    q, k, v = _ops(kh=kh, seed=7)
    qpos, kpos, kval = _full_pos(B, SQ, SKV)
    plan = plan_attn("appro42", "hardware", 8, B, H, kh, SQ, SKV, D,
                     AttnParams(), block=BLOCK, spec=gp.spec)
    got = cim_attention(q, k, v, gp, q_positions=qpos, kv_positions=kpos,
                        kv_valid=kval, block=BLOCK)
    want = _oracle(q, k, v, gp, plan, qpos, kpos, kval)
    assert (np.asarray(got) == np.asarray(want)).all()


# ----------------------------------------------------------- backward ----


def test_attn_ste_backward_is_exact_float_vjp():
    from repro.kernels.attn_gemm import attn_float

    gp = GemmParams(family="appro42", bits=8, mode="hardware")
    q, k, v = _ops(seed=11)
    qpos, kpos, kval = _full_pos(B, SQ, SKV)
    t = lambda a: jnp.transpose(a, (0, 2, 1, 3))  # noqa: E731

    # linear loss: the upstream cotangent is then independent of the
    # (approximate) forward value, so STE == the float VJP exactly
    def loss(a):
        return cim_attention(a, k, v, gp, q_positions=qpos,
                             kv_positions=kpos, kv_valid=kval,
                             block=BLOCK).sum()

    def floss(a):
        return t(attn_float(t(a), t(k), t(v), qpos, kpos, kval)).sum()

    g = jax.grad(loss)(q)
    gf = jax.grad(floss)(q)
    assert np.isfinite(np.asarray(g)).all()
    np.testing.assert_allclose(np.asarray(g), np.asarray(gf),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------- model-layer routing ----


def test_use_cim_attn_gates():
    hw = CiMParams(mode="hardware", family="appro42", attn=True)
    assert _use_cim_attn(hw, is_cross=False)
    assert not _use_cim_attn(hw, is_cross=True)          # cross-attn
    assert not _use_cim_attn(
        CiMParams(mode="hardware", family="appro42"), False)   # attn off
    assert not _use_cim_attn(
        CiMParams(mode="surrogate_fast", family="appro42", attn=True),
        False)                                           # float mode


def test_cim_sdpa_falls_back_on_unsupported_geometry():
    # 16-bit operands: no registered attention kernel -> the helper
    # returns None and the caller keeps the float path
    p = CiMParams(mode="hardware", family="appro42", bits=16, attn=True)
    q, k, v = _ops(seed=13)
    qpos, kpos, kval = _full_pos(B, SQ, SKV)
    out = _cim_sdpa(q, k, v, p, causal=True, window=None,
                    qpos=qpos, kpos=kpos, kval=kval)
    assert out is None


def test_cim_sdpa_per_head_tiers_match_per_family_runs():
    heads = ("exact", "appro42", "appro42", "mitchell")
    p = CiMParams(mode="hardware", family="appro42", attn=True,
                  attn_heads=heads)
    q, k, v = _ops(seed=17)
    qpos, kpos, kval = _full_pos(B, SQ, SKV)
    out = _cim_sdpa(q, k, v, p, causal=True, window=None,
                    qpos=qpos, kpos=kpos, kval=kval)
    assert out is not None and out.shape == q.shape
    # expanding K/V to the per-q-head layout keeps per-head scales, so
    # each head must equal a single-family full run of the same head
    g = H // KH
    ke, ve = jnp.repeat(k, g, axis=2), jnp.repeat(v, g, axis=2)
    for i, fam in enumerate(heads):
        gp = GemmParams(family=fam, bits=8, mode="hardware")
        want = cim_attention(q[:, :, i:i + 1], ke[:, :, i:i + 1],
                             ve[:, :, i:i + 1], gp, q_positions=qpos,
                             kv_positions=kpos, kv_valid=kval)
        assert (np.asarray(out[:, :, i:i + 1])
                == np.asarray(want)).all(), f"head {i} ({fam})"


def test_cim_sdpa_rejects_wrong_head_count():
    p = CiMParams(mode="hardware", family="appro42", attn=True,
                  attn_heads=("exact",))
    q, k, v = _ops(seed=19)
    qpos, kpos, kval = _full_pos(B, SQ, SKV)
    with pytest.raises(ValueError):
        _cim_sdpa(q, k, v, p, causal=True, window=None,
                  qpos=qpos, kpos=kpos, kval=kval)


# --------------------------------------------- _chunked_attn q padding ----


@pytest.mark.parametrize("sq,qc", [(37, 16), (41, 8), (13, 13)])
def test_chunked_attn_prime_sq_pads_instead_of_degrading(sq, qc):
    """Regression (PR 7): `while sq % qc: qc -= 1` degraded to 1-row
    chunks for prime Sq.  The q axis now pads to a chunk multiple; the
    result must stay bit-identical to the unpadded single-chunk run."""
    q, k, v = _ops(sq=sq, skv=sq, seed=23)
    a = _chunked_attn(q, k, v, qc, 16, True, None, 0, sq)
    b = _chunked_attn(q, k, v, sq, 16, True, None, 0, sq)
    assert a.shape == q.shape
    assert (np.asarray(a) == np.asarray(b)).all()


def test_chunked_attn_q_padding_ragged_path():
    sq = 19
    q, k, v = _ops(sq=sq, skv=sq, seed=29)
    pos = jnp.broadcast_to(jnp.arange(sq), (B, sq))
    valid = (pos < jnp.asarray([[11], [19]]))
    info = (pos, pos, valid)
    a = _chunked_attn(q, k, v, 8, 8, True, None, 0, sq, seq_info=info)
    b = _chunked_attn(q, k, v, sq, 8, True, None, 0, sq, seq_info=info)
    assert (np.asarray(a[:, :11]) == np.asarray(b[:, :11])).all()
    assert (np.asarray(a) == np.asarray(b)).all()


# -------------------------------------------------- executable cache ----


def test_attn_zero_retrace_across_buckets_and_tiers():
    tiers = [GemmParams(family="appro42", bits=8, mode="hardware"),
             GemmParams(family="mitchell", bits=8, mode="hardware")]
    shapes = [(2, 21, 29), (2, 27, 31), (1, 9, 11)]   # two seq buckets

    def sweep():
        for gp in tiers:
            for (b, sq, skv) in shapes:
                q, k, v = _ops(b=b, sq=sq, skv=skv, seed=31)
                qpos, kpos, kval = _full_pos(b, sq, skv)
                cim_attention(q, k, v, gp, q_positions=qpos,
                              kv_positions=kpos, kv_valid=kval,
                              block=BLOCK)

    sweep()                                    # build + compile
    t0, n0 = trace_count(), approx_gemm.executable_cache_size()
    sweep()
    assert trace_count() == t0, "steady-state attention calls retraced"
    assert approx_gemm.executable_cache_size() == n0
    # same bucket, different shape: executable reused
    q, k, v = _ops(b=2, sq=24, skv=30, seed=37)
    qpos, kpos, kval = _full_pos(2, 24, 30)
    cim_attention(q, k, v, tiers[0], q_positions=qpos, kv_positions=kpos,
                  kv_valid=kval, block=BLOCK)
    assert approx_gemm.executable_cache_size() == n0


def test_attn_cached_matches_uncached():
    gp = GemmParams(family="log_our", bits=8, mode="hardware")
    q, k, v = _ops(seed=41)
    qpos, kpos, kval = _full_pos(B, SQ, SKV)
    kw = dict(q_positions=qpos, kv_positions=kpos, kv_valid=kval,
              block=BLOCK)
    a = cim_attention(q, k, v, gp, **kw)
    b = cim_attention(q, k, v, gp, cached=False, **kw)
    assert (np.asarray(a) == np.asarray(b)).all()


# ----------------------------------------------------------- autotune ----


def test_attn_autotune_sweep_persists_and_caches(tmp_path):
    cache = os.path.join(tmp_path, "tune.json")
    calls = []

    def fake_measure(block):
        calls.append(block)
        bq, bk = block
        return abs(bq - 32) + abs(bk - 128) + 1.0

    autotune.clear_memory_cache()
    best = autotune.best_attn_block("pallas_attn_lut", 8, 4, 8, 4, 512,
                                    512, 64, backend="tpu",
                                    measure=fake_measure, cache_file=cache)
    assert best == (32, 128)
    assert len(calls) == len(
        autotune.candidate_attn_blocks("pallas_attn_lut", 512, 512))
    autotune.clear_memory_cache()
    calls.clear()
    again = autotune.best_attn_block("pallas_attn_lut", 8, 4, 8, 4, 512,
                                     512, 64, backend="tpu",
                                     measure=fake_measure, cache_file=cache)
    assert again == best and not calls


@pytest.mark.parametrize("garbage", ["{not json", '{"k": [1, "a", 3]}',
                                     '{"k": [1, 2]}'])
def test_attn_autotune_corrupt_cache_hardening(tmp_path, garbage):
    """Shared hardened loader: corrupt payloads are ignored and
    rewritten.  A 2-element row is only valid under an ``:attn`` key —
    under a GEMM/conv key (the `[1, 2]` case) it is malformed."""
    cache = os.path.join(tmp_path, "tune.json")
    with open(cache, "w") as fh:
        fh.write(garbage)
    autotune.clear_memory_cache()
    best = autotune.best_attn_block("pallas_attn_log", 8, 2, 4, 2, 64,
                                    64, 32, backend="tpu",
                                    measure=lambda blk: float(sum(blk)),
                                    cache_file=cache)
    assert best in autotune.candidate_attn_blocks("pallas_attn_log", 64,
                                                  64)
    with open(cache) as fh:
        disk = json.load(fh)
    assert list(disk.values()) == [list(best)]


def test_attn_autotune_row_arity_is_key_aware(tmp_path):
    cache = os.path.join(tmp_path, "tune.json")
    attn_key = autotune.attn_cache_key("pallas_attn_lut", 8, 2, 4, 2, 64,
                                       64, 32, "tpu")
    with open(cache, "w") as fh:
        json.dump({attn_key: [16, 64],          # valid attn pair
                   "pallas_gemm_lut:b8:m8k64n128:tpu": [16, 64],  # bad
                   "pallas_attn_lut:b8:attn8x4x2x64x64x16:tpu":
                       [16, 64, 128]},          # bad: attn rows are pairs
                  fh)
    loaded = autotune._load_disk(cache)
    assert loaded == {attn_key: (16, 64)}


def test_attn_bucket_keeps_heads_and_head_dim_exact():
    assert autotune.bucket_attn(3, 8, 4, 33, 47, 64) \
        == (8, 8, 4, 64, 64, 64)
    k1 = autotune.attn_cache_key("pallas_attn_lut", 8, 3, 8, 4, 33, 47,
                                 64, "cpu")
    k2 = autotune.attn_cache_key("pallas_attn_lut", 8, 4, 8, 4, 40, 50,
                                 64, "cpu")
    assert k1 == k2                    # same bucket, one plan
    k3 = autotune.attn_cache_key("pallas_attn_lut", 8, 3, 8, 4, 33, 47,
                                 128, "cpu")
    assert k1 != k3                    # head_dim changes the lane padding


def test_attn_autotune_off_tpu_never_writes_disk(tmp_path, monkeypatch):
    cache = os.path.join(tmp_path, "never.json")
    monkeypatch.setenv("OPENACM_AUTOTUNE_CACHE", cache)
    autotune.clear_memory_cache()
    blk = autotune.best_attn_block("pallas_attn_lut", 8, 2, 4, 2, 64, 64,
                                   32, backend="cpu")
    assert blk == autotune.heuristic_attn_block("pallas_attn_lut", 64, 64)
    assert not os.path.exists(cache)
