"""Hypothesis-driven scheduler property tests (the oracles live in
tests/test_serving.py::check_random_trace / check_spec_trace): no slot
leak, no starvation, eviction frees capacity, token budget respected,
and — for speculative decoding — per-request sequences preserved
across seeded variable-length draft/verify emissions, over randomized
arrival traces and both admission policies.

Profiles are explicit so CI is deterministic and budgeted: ``ci``
(derandomized, no wall-clock deadline — CI boxes stall unpredictably)
is selected by ``HYPOTHESIS_PROFILE=ci`` in the workflow; the default
``dev`` profile keeps hypothesis's random exploration (and database)
for local runs.  Both keep shrinking enabled: a failing trace minimizes
to the shortest arrival/accept pattern that breaks the scheduler."""

import os

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need the optional "
    "hypothesis dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_serving import check_random_trace, check_spec_trace  # noqa: E402

settings.register_profile("ci", max_examples=40, deadline=None,
                          derandomize=True)
settings.register_profile("dev", max_examples=40, deadline=None)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))

req_st = st.tuples(st.floats(0.0, 0.5), st.integers(1, 8),
                   st.integers(1, 6), st.integers(0, 1))

# spec traces: (gap, prompt_len, max_new) — tier is always the spec lane
spec_req_st = st.tuples(st.floats(0.0, 0.5), st.integers(1, 8),
                        st.integers(1, 9))


@given(st.lists(req_st, min_size=1, max_size=25),
       st.integers(1, 3), st.booleans())
def test_scheduler_properties_random_traces(spec, n_slots, continuous):
    check_random_trace(spec, n_slots, continuous)


@given(st.lists(spec_req_st, min_size=1, max_size=25),
       st.integers(1, 3), st.integers(1, 4), st.integers(0, 2 ** 16),
       st.booleans(), st.integers(1, 4))
def test_spec_scheduler_properties_random_traces(spec, n_slots, k,
                                                 accept_seed, continuous,
                                                 rounds):
    """Randomized draft/verify acceptance traces: however many tokens
    each spec call emits per slot (across 1..4 fused sub-rounds), the
    scheduler's accounting and every request's final sequence must be
    exactly sequential-decode's."""
    check_spec_trace(spec, n_slots, k, accept_seed, continuous,
                     rounds=rounds)
