"""Hypothesis-driven scheduler property tests (the oracle lives in
tests/test_serving.py::check_random_trace): no slot leak, no
starvation, eviction frees capacity, token budget respected, over
randomized arrival traces and both admission policies."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need the optional "
    "hypothesis dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_serving import check_random_trace  # noqa: E402

req_st = st.tuples(st.floats(0.0, 0.5), st.integers(1, 8),
                   st.integers(1, 6), st.integers(0, 1))


@given(st.lists(req_st, min_size=1, max_size=25),
       st.integers(1, 3), st.booleans())
@settings(max_examples=40, deadline=None)
def test_scheduler_properties_random_traces(spec, n_slots, continuous):
    check_random_trace(spec, n_slots, continuous)
