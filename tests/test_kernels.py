"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in kernels/ref.py (interpret mode on CPU, per DESIGN.md)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.luts import signed_product_lut
from repro.core.multipliers import MultiplierSpec
from repro.kernels import ops, ref

SHAPES = [(8, 16, 8), (33, 70, 17), (64, 64, 64), (128, 96, 40)]


def _ops(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-127, 128, (m, k), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, (k, n), dtype=np.int8))
    return xq, wq


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("family", ["exact", "appro42", "log_our"])
def test_lut_kernel_matches_ref(shape, family):
    m, k, n = shape
    xq, wq = _ops(m, k, n)
    spec = MultiplierSpec(family, 8, signed=True)
    lut = jnp.asarray(signed_product_lut(spec).ravel())
    want = ref.lut_matmul_ref(xq, wq, lut)
    got = ops.approx_matmul_bit_exact(xq, wq, spec)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_lut_kernel_exact_family_is_integer_matmul():
    xq, wq = _ops(40, 30, 20)
    spec = MultiplierSpec("exact", 8, signed=True)
    got = ops.approx_matmul_bit_exact(xq, wq, spec)
    want = np.asarray(xq, np.int32) @ np.asarray(wq, np.int32)
    assert (np.asarray(got) == want).all()


@pytest.mark.parametrize("k_slice", [4, 16, 64])
def test_lut_kernel_k_slice_invariant(k_slice):
    """The k-sliced gather (bounding the live index tensor) is exact for
    any slice width."""
    from repro.kernels.approx_matmul import lut_matmul

    xq, wq = _ops(33, 70, 17, seed=2)
    spec = MultiplierSpec("appro42", 8, signed=True)
    lut = jnp.asarray(signed_product_lut(spec).ravel())
    want = ref.lut_matmul_ref(xq, wq, lut)
    got = lut_matmul(xq, wq, lut, block=(32, 32, 128), k_slice=k_slice)
    assert (np.asarray(want) == np.asarray(got)).all()


# ------------------------------------------------- nibble sub-LUT path ----


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("family,nac", [("exact", None), ("appro42", 4),
                                        ("appro42", 2)])
def test_nibble_kernel_matches_ref(shape, family, nac):
    """Nibble-decomposed kernel is bit-identical to the full-LUT oracle
    for every family/shape it routes (ragged shapes exercise padding)."""
    m, k, n = shape
    xq, wq = _ops(m, k, n, seed=5)
    spec = MultiplierSpec(family, 8, signed=True, n_approx_cols=nac)
    lut = jnp.asarray(signed_product_lut(spec).ravel())
    want = ref.lut_matmul_ref(xq, wq, lut)
    got = ops.nibble_matmul_bit_exact(xq, wq, spec)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_nibble_kernel_saturates_int8_min_like_signed_lut():
    """|-128| saturates to 127 in the signed LUT's sign-magnitude
    wrapper; the nibble kernel must agree on the int-in oracle surface
    (quantization never emits -128, but run_int_kernel can see it)."""
    xq = jnp.asarray([[-128, 3], [-128, -128]], jnp.int8)
    wq = jnp.asarray([[5, -128], [7, 1]], jnp.int8)
    spec = MultiplierSpec("exact", 8, signed=True)
    lut = jnp.asarray(signed_product_lut(spec).ravel())
    want = ref.lut_matmul_ref(xq, wq, lut)
    got = ops.nibble_matmul_bit_exact(xq, wq, spec)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_nibble_rejects_undecomposable_family():
    from repro.core.luts import nibble_decomposable

    spec = MultiplierSpec("appro42", 8, signed=True)   # 8 approx cols
    assert not nibble_decomposable(spec)
    xq, wq = _ops(8, 8, 8)
    with pytest.raises(ValueError, match="not nibble-decomposable"):
        ops.nibble_matmul_bit_exact(xq, wq, spec)


# ------------------------------------------- fused-quantization kernels ----


def _quant_pipeline(x, w, bits=8):
    from repro.core.quantization import quant_scale, quantize

    sx = quant_scale(x, bits)
    sw = quant_scale(w, bits, axis=0)
    return quantize(x, sx, bits), sx, quantize(w, sw, bits), sw


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_lut_kernel_equals_quantize_kernel_dequantize(shape):
    """One-pallas_call fused kernel == the 3-pass pipeline, bit for bit
    (same integer core, same f32 epilogue order)."""
    m, k, n = shape
    kx, kw = jax.random.split(jax.random.PRNGKey(m + n))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    spec = MultiplierSpec("appro42", 8, signed=True)
    xq, sx, wq, sw = _quant_pipeline(x, w)
    want = (ops.approx_matmul_bit_exact(xq, wq, spec)
            .astype(jnp.float32) * sx) * sw
    got = ops.approx_matmul_fused(x, w, spec)
    assert (np.asarray(want) == np.asarray(got)).all()


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_nibble_kernel_equals_pipeline(shape):
    m, k, n = shape
    kx, kw = jax.random.split(jax.random.PRNGKey(m + n + 1))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    spec = MultiplierSpec("exact", 8, signed=True)
    xq, sx, wq, sw = _quant_pipeline(x, w)
    want = (ops.nibble_matmul_bit_exact(xq, wq, spec)
            .astype(jnp.float32) * sx) * sw
    got = ops.nibble_matmul_fused(x, w, spec)
    assert (np.asarray(want) == np.asarray(got)).all()


@pytest.mark.parametrize("compensated", [False, True])
def test_fused_log_kernel_equals_pipeline(compensated):
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (33, 70))
    w = jax.random.normal(kw, (70, 17))
    xq, sx, wq, sw = _quant_pipeline(x, w)
    want = (ops.log_matmul(xq, wq, compensated=compensated)
            .astype(jnp.float32) * sx) * sw
    got = ops.log_matmul_fused(x, w, compensated=compensated)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_fused_surrogate_kernel_matches_ref_epilogue():
    """cim_gemm_fused runs quantization + the full surrogate epilogue
    (scale, bias, noise) in one pallas_call; must match the XLA ref."""
    kx, kw = jax.random.split(jax.random.PRNGKey(9))
    x = jax.random.normal(kx, (33, 70))
    w = jax.random.normal(kw, (70, 17))
    eps = jax.random.normal(jax.random.PRNGKey(10), (33, 17))
    xq, sx, wq, sw = _quant_pipeline(x, w)
    mu, c0, c1 = -0.013, 1480.0, 2.1e-4
    want = ref.cim_gemm_ref(xq, wq, sx, jnp.ravel(sw), eps, mu, c0, c1)
    got = ops.surrogate_gemm_fused(x, w, eps, mu, c0, c1)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=3e-5, atol=3e-5)
    # deterministic variant (eps=None): bias term only
    det = ops.surrogate_gemm_fused(x, w, None, mu, c0, c1)
    want_det = (1.0 + mu) * (xq.astype(jnp.float32)
                             @ wq.astype(jnp.float32)) * (sx * sw)
    np.testing.assert_allclose(np.asarray(det), np.asarray(want_det),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------- LUT padding invariant ----


def test_signed_lut_annihilates_zero_for_all_families():
    """The Pallas kernels zero-pad ragged tiles; every family's signed
    LUT must map (0, b) and (a, 0) to 0 (asserted at build time)."""
    for family in ("exact", "appro42", "mitchell", "log_our"):
        lut = signed_product_lut(MultiplierSpec(family, 8, signed=True))
        half = 1 << 7
        assert not lut[half, :].any() and not lut[:, half].any()


def test_lut_build_rejects_non_annihilating_table():
    """A signed table violating 0*b == 0 must fail loudly at LUT build
    time instead of silently corrupting ragged (zero-padded) shapes."""
    from repro.core.luts import assert_zero_annihilation

    n = 16
    bad = np.zeros((n, n), np.int64)
    bad[n // 2, 3] = 7       # approximate cell emitting garbage at zero
    with pytest.raises(AssertionError, match="annihilate"):
        assert_zero_annihilation(bad, n // 2, "bad4b")
    bad[:] = 0
    assert_zero_annihilation(bad, n // 2, "good4b")   # no raise


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("compensated", [False, True])
def test_mitchell_kernel_matches_ref(shape, compensated):
    m, k, n = shape
    xq, wq = _ops(m, k, n, seed=3)
    want = ref.mitchell_matmul_ref(xq, wq, compensated=compensated)
    got = ops.log_matmul(xq, wq, compensated=compensated)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_mitchell_kernel_matches_core_emulator():
    """Kernel semantics == the gate-level numpy emulator (cross-stack)."""
    from repro.core.multipliers import multiply

    rng = np.random.default_rng(7)
    a = rng.integers(-127, 128, 256)
    b = rng.integers(-127, 128, 256)
    spec = MultiplierSpec("log_our", 8, signed=True)
    core = multiply(a, b, spec)
    k = ops.log_matmul(jnp.asarray(a[:, None], jnp.int8),
                       jnp.asarray(b[:, None].T, jnp.int8))
    # kernel computes full outer product; diagonal == elementwise products
    assert (np.diag(np.asarray(k)) == core).all()


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("block", [(32, 32, 32), (128, 128, 128)])
def test_cim_gemm_matches_ref(shape, block):
    m, k, n = shape
    xq, wq = _ops(m, k, n, seed=11)
    rng = np.random.default_rng(12)
    sx = jnp.float32(0.017)
    sw = jnp.asarray(rng.uniform(0.005, 0.02, n).astype(np.float32))
    eps = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    args = (xq, wq, sx, sw, eps, -0.013, 1480.0, 2.1e-4)
    want = ref.cim_gemm_ref(*args)
    got = ops.surrogate_gemm(*args, block=block)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=3e-5, atol=3e-5)


def test_cim_gemm_core_int_dot_is_exact():
    xq, wq = _ops(50, 129, 31, seed=5)
    d, sq = ops.cim_gemm_core(xq, wq, need_sq=True, interpret=True)
    want = np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    assert (np.asarray(d, np.int64) == want).all()
    want_sq = (np.asarray(xq, np.float64) ** 2) @ (np.asarray(wq, np.float64) ** 2)
    np.testing.assert_allclose(np.asarray(sq), want_sq, rtol=1e-5)


def test_kernel_dtype_sweep_int8_vs_int32_operands():
    """LUT kernel accepts wider operand dtypes carrying int8 values."""
    xq, wq = _ops(16, 32, 8)
    spec = MultiplierSpec("appro42", 8, signed=True)
    got8 = ops.approx_matmul_bit_exact(xq, wq, spec)
    got32 = ops.approx_matmul_bit_exact(xq.astype(jnp.int32),
                                        wq.astype(jnp.int32), spec)
    assert (np.asarray(got8) == np.asarray(got32)).all()


@pytest.mark.parametrize("t,block_t", [(16, 4), (32, 8), (64, 64), (48, 13)])
def test_slstm_scan_kernel_matches_ref(t, block_t):
    from repro.kernels.ref import slstm_scan_ref
    from repro.kernels.slstm_scan import slstm_scan

    b, nh, dh = 2, 2, 8
    keys = jax.random.split(jax.random.PRNGKey(t), 3)
    u = jax.random.normal(keys[0], (b, t, 4 * nh * dh))
    r = jax.random.normal(keys[1], (nh, dh, 4 * dh)) * 0.05
    bias = jax.random.normal(keys[2], (nh, 4 * dh)) * 0.1
    want = slstm_scan_ref(u, r, bias, nh)
    got = slstm_scan(u, r, bias, nh, block_t=block_t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_slstm_scan_kernel_matches_model_cell():
    """Kernel semantics == the model's sLSTM block cell (cross-stack)."""
    from repro.kernels.slstm_scan import slstm_scan
    from repro.models.xlstm import _slstm_cell

    b, t, nh, dh = 1, 12, 2, 4
    d = nh * dh
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    u = jax.random.normal(keys[0], (b, t, 4 * d))
    r = jax.random.normal(keys[1], (nh, dh, 4 * dh)) * 0.05
    bias = jax.random.normal(keys[2], (nh, 4 * dh)) * 0.1
    params = {"r": type("P", (), {"value": r})(),
              "b": type("P", (), {"value": bias.reshape(-1)})()}
    state = tuple(jnp.zeros((b, nh, dh)) for _ in range(4))
    hs = []
    for i in range(t):
        # _slstm_cell reshapes u_t to (b, nh, 4dh); our u is laid out
        # head-major already
        state = _slstm_cell(params, u[:, i], state, nh)
        hs.append(state[2])
    want = jnp.stack(hs, axis=1)
    got = slstm_scan(u, r, bias, nh, block_t=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
