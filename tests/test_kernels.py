"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in kernels/ref.py (interpret mode on CPU, per DESIGN.md)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.luts import signed_product_lut
from repro.core.multipliers import MultiplierSpec
from repro.kernels import ops, ref

SHAPES = [(8, 16, 8), (33, 70, 17), (64, 64, 64), (128, 96, 40)]


def _ops(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-127, 128, (m, k), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, (k, n), dtype=np.int8))
    return xq, wq


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("family", ["exact", "appro42", "log_our"])
def test_lut_kernel_matches_ref(shape, family):
    m, k, n = shape
    xq, wq = _ops(m, k, n)
    spec = MultiplierSpec(family, 8, signed=True)
    lut = jnp.asarray(signed_product_lut(spec).ravel())
    want = ref.lut_matmul_ref(xq, wq, lut)
    got = ops.approx_matmul_bit_exact(xq, wq, spec)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_lut_kernel_exact_family_is_integer_matmul():
    xq, wq = _ops(40, 30, 20)
    spec = MultiplierSpec("exact", 8, signed=True)
    got = ops.approx_matmul_bit_exact(xq, wq, spec)
    want = np.asarray(xq, np.int32) @ np.asarray(wq, np.int32)
    assert (np.asarray(got) == want).all()


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("compensated", [False, True])
def test_mitchell_kernel_matches_ref(shape, compensated):
    m, k, n = shape
    xq, wq = _ops(m, k, n, seed=3)
    want = ref.mitchell_matmul_ref(xq, wq, compensated=compensated)
    got = ops.log_matmul(xq, wq, compensated=compensated)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_mitchell_kernel_matches_core_emulator():
    """Kernel semantics == the gate-level numpy emulator (cross-stack)."""
    from repro.core.multipliers import multiply

    rng = np.random.default_rng(7)
    a = rng.integers(-127, 128, 256)
    b = rng.integers(-127, 128, 256)
    spec = MultiplierSpec("log_our", 8, signed=True)
    core = multiply(a, b, spec)
    k = ops.log_matmul(jnp.asarray(a[:, None], jnp.int8),
                       jnp.asarray(b[:, None].T, jnp.int8))
    # kernel computes full outer product; diagonal == elementwise products
    assert (np.diag(np.asarray(k)) == core).all()


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("block", [(32, 32, 32), (128, 128, 128)])
def test_cim_gemm_matches_ref(shape, block):
    m, k, n = shape
    xq, wq = _ops(m, k, n, seed=11)
    rng = np.random.default_rng(12)
    sx = jnp.float32(0.017)
    sw = jnp.asarray(rng.uniform(0.005, 0.02, n).astype(np.float32))
    eps = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    args = (xq, wq, sx, sw, eps, -0.013, 1480.0, 2.1e-4)
    want = ref.cim_gemm_ref(*args)
    got = ops.surrogate_gemm(*args, block=block)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=3e-5, atol=3e-5)


def test_cim_gemm_core_int_dot_is_exact():
    xq, wq = _ops(50, 129, 31, seed=5)
    d, sq = ops.cim_gemm_core(xq, wq, need_sq=True, interpret=True)
    want = np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    assert (np.asarray(d, np.int64) == want).all()
    want_sq = (np.asarray(xq, np.float64) ** 2) @ (np.asarray(wq, np.float64) ** 2)
    np.testing.assert_allclose(np.asarray(sq), want_sq, rtol=1e-5)


def test_kernel_dtype_sweep_int8_vs_int32_operands():
    """LUT kernel accepts wider operand dtypes carrying int8 values."""
    xq, wq = _ops(16, 32, 8)
    spec = MultiplierSpec("appro42", 8, signed=True)
    got8 = ops.approx_matmul_bit_exact(xq, wq, spec)
    got32 = ops.approx_matmul_bit_exact(xq.astype(jnp.int32),
                                        wq.astype(jnp.int32), spec)
    assert (np.asarray(got8) == np.asarray(got32)).all()


@pytest.mark.parametrize("t,block_t", [(16, 4), (32, 8), (64, 64), (48, 13)])
def test_slstm_scan_kernel_matches_ref(t, block_t):
    from repro.kernels.ref import slstm_scan_ref
    from repro.kernels.slstm_scan import slstm_scan

    b, nh, dh = 2, 2, 8
    keys = jax.random.split(jax.random.PRNGKey(t), 3)
    u = jax.random.normal(keys[0], (b, t, 4 * nh * dh))
    r = jax.random.normal(keys[1], (nh, dh, 4 * dh)) * 0.05
    bias = jax.random.normal(keys[2], (nh, 4 * dh)) * 0.1
    want = slstm_scan_ref(u, r, bias, nh)
    got = slstm_scan(u, r, bias, nh, block_t=block_t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_slstm_scan_kernel_matches_model_cell():
    """Kernel semantics == the model's sLSTM block cell (cross-stack)."""
    from repro.kernels.slstm_scan import slstm_scan
    from repro.models.xlstm import _slstm_cell

    b, t, nh, dh = 1, 12, 2, 4
    d = nh * dh
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    u = jax.random.normal(keys[0], (b, t, 4 * d))
    r = jax.random.normal(keys[1], (nh, dh, 4 * dh)) * 0.05
    bias = jax.random.normal(keys[2], (nh, 4 * dh)) * 0.1
    params = {"r": type("P", (), {"value": r})(),
              "b": type("P", (), {"value": bias.reshape(-1)})()}
    state = tuple(jnp.zeros((b, nh, dh)) for _ in range(4))
    hs = []
    for i in range(t):
        # _slstm_cell reshapes u_t to (b, nh, 4dh); our u is laid out
        # head-major already
        state = _slstm_cell(params, u[:, i], state, nh)
        hs.append(state[2])
    want = jnp.stack(hs, axis=1)
    got = slstm_scan(u, r, bias, nh, block_t=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
