"""Lane sentinels, circuit breaker and graceful degradation
(serving/sentinel.py + engine integration, DESIGN.md §14).

Two layers, mirroring test_serving.py:

  * pure-host units: SentinelConfig thresholds, rolling stats, breaker
    state machine, the drift statistic, and LaneSentinel.observe over
    synthetic logits;
  * scheduler integration against fake lanes (no jax): a scripted trip
    must quarantine the lane, discard its fault-suspect tokens, restart
    in-flight requests on the safest healthy lane, honor the retry
    budget and backoff, re-admit through the half-open probe, demote
    pinned routing around quarantined tiers, and bound admission with
    structured backpressure.

The real-LM differential acceptance run (fault injected at the
Table V-characterized rate -> trip -> demote -> token-for-token
identity with an exact-lane-only run) lives in benchmarks/
bench_faults.py; test_serve_consistency.py keeps the underlying
exact-lane invariants honest.
"""

import numpy as np
import pytest

from repro.serving import (AdmissionRejected, CircuitBreaker,
                           EngineStats, LaneHealthError, Request,
                           RollingStats, SentinelConfig, ServingEngine,
                           SimClock)
from repro.serving.engine import LMLaneBackend
from repro.serving.sentinel import (HALF_OPEN, HEALTHY, TRIPPED,
                                    LaneSentinel, logit_drift)
from repro.serving.tiers import AccuracyTier, TierRouter

from test_serving import FakeLane, _fake_tiers, _req


# ------------------------------------------------------------- units ----


def test_sentinel_config_validation():
    for kw in ({"period": 0}, {"window": 0}, {"probe_rounds": 0},
               {"min_agree": 1.5}):
        with pytest.raises(ValueError):
            SentinelConfig(**kw)
    cfg = SentinelConfig(nmed_factor=10.0, nmed_floor=0.25)
    assert cfg.nmed_threshold(0.0) == 0.25        # floor for near-exact
    assert cfg.nmed_threshold(0.1) == pytest.approx(1.0)


def test_rolling_stats_window():
    st = RollingStats(window=3)
    assert st.agree == 1.0 and st.nmed == 0.0     # benign defaults
    for a in (0.0, 0.0, 0.0, 1.0, 1.0, 1.0):
        st.push(a, 0.5)
    assert st.n == 3 and st.agree == 1.0          # old samples evicted
    st.reset()
    assert st.n == 0 and st.agree == 1.0


def test_breaker_state_machine():
    br = CircuitBreaker(cooldown_s=1.0)
    assert br.state == HEALTHY
    with pytest.raises(RuntimeError):
        br.probe_started()                        # healthy: no probe
    br.trip(now=10.0)
    assert br.state == TRIPPED and br.n_trips == 1
    assert not br.should_probe(10.5)              # cooling down
    assert br.should_probe(11.0)
    br.probe_started()
    assert br.state == HALF_OPEN
    br.probe_failed(now=11.0)
    assert br.state == TRIPPED and not br.should_probe(11.5)
    br.probe_started() if br.should_probe(12.0) else None
    br.probe_passed()
    assert br.state == HEALTHY and br.n_recoveries == 1


def test_logit_drift_statistic():
    ref = np.array([[1.0, 2.0, 4.0], [1.0, 2.0, 4.0]])
    agree, nmed = logit_drift(ref, ref, slots=[0, 1])
    assert agree == 1.0 and nmed == pytest.approx(0.0)
    lane = np.array([[4.0, 2.0, 1.0],             # argmax flipped
                     [1.0, 2.0, 4.0]])
    agree, nmed = logit_drift(lane, ref, slots=[0, 1])
    assert agree == 0.5
    # slot 0: mean|a-e| = 2, mean|e| = 7/3 -> 6/7; slot 1 exact
    assert nmed == pytest.approx(0.5 * 6 / 7)
    agree, _ = logit_drift(lane, ref, slots=[1])  # dead slots ignored
    assert agree == 1.0


def _sentinel(envelope=0.02, **kw):
    cfg = SentinelConfig(period=1, window=2, min_samples=2, **kw)
    return LaneSentinel(lm=None, params=None, envelope=envelope, cfg=cfg)


def test_observe_trips_on_nmed_after_min_samples():
    sen = _sentinel()
    ref = np.ones((1, 8))
    bad = np.full((1, 8), 50.0)
    assert sen.due()
    assert not sen.observe(bad, ref, [0], now=0.0)   # 1 < min_samples
    assert sen.due()
    assert sen.observe(bad, ref, [0], now=0.1)
    assert sen.tripped and "NMED" in sen.last_trip_reason
    assert sen.last_detection_rounds == 2


def test_observe_trips_on_agreement():
    sen = _sentinel(min_agree=0.9)
    ref = np.tile(np.array([[0.0, 1.0]]), (1, 1))
    flipped = np.array([[1.0, 0.999]])               # tiny NMED, wrong argmax
    sen.due(), sen.observe(flipped, ref, [0], 0.0)
    sen.due()
    assert sen.observe(flipped, ref, [0], 0.1)
    assert "agreement" in sen.last_trip_reason


def test_observe_trips_immediately_on_nonfinite():
    sen = _sentinel()
    ref = np.ones((1, 4))
    lane = np.array([[1.0, np.nan, 1.0, 1.0]])
    sen.due()
    assert sen.observe(lane, ref, [0], 0.0)          # no min_samples wait
    assert "non-finite" in sen.last_trip_reason


def test_greedy_guard_raises_lane_health_error():
    lg = np.zeros((2, 1, 4), np.float32)
    lg[1, 0, 2] = np.inf
    with pytest.raises(LaneHealthError, match="non-finite"):
        LMLaneBackend._greedy(None, lg)


# ----------------------------------------- scheduler integration --------


class FakeSentinel:
    """LaneSentinel double: scripted trip after `trip_at` checks,
    scripted probe verdict — drives the engine's quarantine machinery
    without jax."""

    def __init__(self, trip_at=2, probe_ok=True):
        self.trip_at, self.probe_ok = trip_at, probe_ok
        self.checks = 0
        self.breaker = CircuitBreaker(cooldown_s=0.0)
        self.last_trip_reason = None

    def warmup(self, backend):
        return 0

    def due(self):
        return True

    def shadow(self, backend):
        return np.zeros(1)

    def observe(self, lane_logits, ref, slots, now):
        self.checks += 1
        if self.checks == self.trip_at:
            self.last_trip_reason = "scripted drift"
            self.breaker.trip(now)
            return True
        return False

    def record_failure(self, now, reason):
        self.last_trip_reason = reason
        self.breaker.trip(now)

    def probe(self, backend, slot, now):
        self.breaker.probe_started()
        if self.probe_ok:
            self.breaker.probe_passed()
        else:
            self.breaker.probe_failed(now)
        return self.probe_ok


def _guarded_engine(trip_at=2, probe_ok=False, **kw):
    tiers = _fake_tiers(("a", "b"))       # a: nmed 0.000, b: 0.001
    lanes = {t.name: FakeLane(3) for t in tiers}
    for lane in lanes.values():
        lane.last_decode_logits = None    # engine reads it post-decode
    sen = FakeSentinel(trip_at=trip_at, probe_ok=probe_ok)
    eng = ServingEngine(lanes, TierRouter(tiers), check_invariants=True,
                        sentinels={"b": sen}, **kw)
    return eng, sen


def test_trip_restarts_in_flight_on_safest_lane():
    eng, sen = _guarded_engine(trip_at=2, probe_ok=False)
    reqs = [_req(i, tier="b", max_new=5) for i in range(2)]
    res = eng.run(reqs, clock=SimClock())
    assert len(eng.trip_log) == 1
    t = eng.trip_log[0]
    assert t["lane"] == "b" and t["in_flight_displaced"] == 2
    assert t["tokens_before_trip"] == 4   # 2 slots x 2 emitted rounds
    for r in res.values():
        assert r.done and r.status == "ok"
        assert r.tier == "a" and r.retries == 1
        assert len(r.tokens) == 5
        # fault-suspect tokens discarded: the sequence is one fresh
        # contiguous counter run from the healthy lane's admission
        assert r.tokens == list(range(r.tokens[0], r.tokens[0] + 5))
    assert eng.lanes["b"].quarantined     # probe keeps failing
    assert eng.active_tokens == 0


def test_queued_requests_reroute_without_retry_penalty():
    eng, _ = _guarded_engine(trip_at=1, probe_ok=False)
    running = [_req(0, tier="b", max_new=4)]
    queued = [_req(i, tier="b", max_new=2, arrival=0.0)
              for i in range(1, 6)]      # > n_slots: some stay queued
    res = eng.run(running + queued, clock=SimClock())
    assert all(r.done and r.status == "ok" for r in res.values())
    displaced = [r for r in res.values() if r.retries]
    rerouted = [r for r in res.values() if not r.retries]
    assert displaced and rerouted        # both paths exercised
    assert all(r.tier == "a" for r in res.values())


def test_probe_readmits_lane():
    eng, sen = _guarded_engine(trip_at=2, probe_ok=True)
    res = eng.run([_req(0, tier="b", max_new=6)], clock=SimClock())
    assert res[0].done and res[0].tier == "a"
    assert not eng.lanes["b"].quarantined
    assert sen.breaker.n_recoveries == 1
    assert eng.submit(_req(7, tier="b")) == "b"   # takes traffic again


def test_retry_budget_exhaustion_marks_failed():
    eng, _ = _guarded_engine(trip_at=2, probe_ok=False, retry_budget=0)
    res = eng.run([_req(0, tier="b", max_new=5)], clock=SimClock())
    assert res[0].status == "failed" and res[0].done
    assert res[0].retries == 1
    stats = EngineStats.from_results(res, 1.0)
    assert stats.n_failed == 1 and stats.total_tokens == 0


def test_retry_backoff_defers_restart():
    eng, _ = _guarded_engine(trip_at=2, probe_ok=False,
                             retry_backoff_s=0.5)
    clock = SimClock()
    res = eng.run([_req(0, tier="b", max_new=4)], clock=clock)
    assert res[0].done and res[0].status == "ok" and res[0].retries == 1
    assert clock.t >= 0.5                 # waited out the backoff
    assert res[0].t_admit >= 0.5


def test_trip_on_lane_health_error_during_decode():
    class SickLane(FakeLane):
        def decode_round(self):
            raise LaneHealthError("non-finite logits (test)")

    tiers = _fake_tiers(("a", "b"))
    lanes = {"a": FakeLane(3), "b": SickLane(3)}
    lanes["a"].last_decode_logits = None
    sen = FakeSentinel(trip_at=10 ** 9)
    eng = ServingEngine(lanes, TierRouter(tiers), check_invariants=True,
                        sentinels={"b": sen})
    res = eng.run([_req(0, tier="b", max_new=3)], clock=SimClock())
    assert res[0].done and res[0].tier == "a" and res[0].retries == 1
    assert "non-finite" in eng.trip_log[0]["reason"]
    assert sen.breaker.n_trips == 1


def test_health_error_without_sentinel_propagates():
    class SickLane(FakeLane):
        def decode_round(self):
            raise LaneHealthError("boom")

    tiers = _fake_tiers(("a",))
    eng = ServingEngine({"a": SickLane(2)}, TierRouter(tiers))
    with pytest.raises(LaneHealthError):
        eng.run([_req(0, tier="a")], clock=SimClock())


def test_router_demotes_pinned_tier_around_quarantine():
    tiers = [AccuracyTier("exact", None, 0.0, 3.0),
             AccuracyTier("balanced", None, 0.01, 2.0),
             AccuracyTier("economy", None, 0.05, 1.0)]
    router = TierRouter(tiers)
    # pinned economy, economy down -> balanced (nmed <= economy's;
    # cheapest energy among the not-worse healthy rungs)
    assert router.route(None, "economy",
                        avoid={"economy"}).name == "balanced"
    assert router.route(None, "balanced",
                        avoid={"balanced", "economy"}).name == "exact"
    with pytest.raises(ValueError):
        router.route(None, "exact", avoid={"exact"})
    # tolerance routing skips quarantined rungs too
    assert router.route(0.1, None, avoid={"economy"}).name == "balanced"


def test_admission_backpressure():
    eng, _ = _guarded_engine(trip_at=10 ** 9, max_queued=2)
    eng.submit(_req(0, tier="b"))
    eng.submit(_req(1, tier="b"))
    with pytest.raises(AdmissionRejected) as ei:
        eng.submit(_req(2, tier="b"))
    assert ei.value.rid == 2 and ei.value.queued == 2
    assert ei.value.limit == 2
    assert 2 not in eng.results          # rejected: no result entry


def test_backpressure_holds_arrivals_until_drain():
    eng, _ = _guarded_engine(trip_at=10 ** 9, max_queued=1)
    reqs = [_req(i, tier="a", max_new=2, arrival=0.0) for i in range(8)]
    res = eng.run(reqs, clock=SimClock())
    assert len(res) == 8                  # held, not dropped
    assert all(r.done and r.status == "ok" for r in res.values())


def test_build_engine_rejects_fault_on_mesh():
    from repro.configs import get_config
    from repro.core.faults import FaultConfig
    from repro.serving import build_engine

    cfg = get_config("qwen3-1.7b", smoke=True)
    with pytest.raises(ValueError, match="mesh"):
        build_engine(cfg, fault=FaultConfig(p_sa0=0.01),
                     mesh=object())
