"""Hypothesis property variant of the allocation-budget invariant (the
always-running seeded sweep lives in tests/test_allocate.py): for ANY
budget, the allocation `autoallocate` returns satisfies it under exact
re-evaluation — the search may be wrong, the measurement gate may not.

Profiles mirror tests/test_serving_properties.py: ``ci`` (derandomized,
no deadline) via HYPOTHESIS_PROFILE=ci, default ``dev`` keeps random
exploration."""

import os

import jax
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need the optional "
    "hypothesis dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import allocate  # noqa: E402
from repro.models.transformer import LM  # noqa: E402

settings.register_profile("ci", max_examples=8, deadline=None,
                          derandomize=True)
settings.register_profile("dev", max_examples=8, deadline=None)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))

_STATE = {}


def _evaluator():
    if "ev" not in _STATE:
        cfg = get_config("qwen3-1.7b", smoke=True)
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)}
        _STATE["lm"] = lm
        _STATE["ev"] = allocate.make_evaluator(
            lm, params=params, batch=batch,
            modules=("wq", "wv", "mlp_wo"))
    return _STATE["lm"], _STATE["ev"]


@given(st.floats(1e-4, 5e-2), st.integers(0, 3))
def test_budget_satisfied_under_exact_reevaluation(budget, seed):
    lm, ev = _evaluator()
    a = allocate.autoallocate(lm, budget, evaluator=ev, seed=seed)
    assert a.nmed <= budget
    assert a.energy_per_mac_j <= a.exact_energy_per_mac_j
