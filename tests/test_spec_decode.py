"""Cross-tier speculative decoding (DESIGN.md §12).

Coverage layers:

  * **differential bit-identity** — the spec engine's emitted token
    sequences equal the plain per-token exact engine's, token for
    token, for every draft depth k in {1, 2, 4, 8}, over ragged
    mixed-tier Poisson workloads.  ONE pre-warmed backend serves all
    depths via `set_draft_k`, so the sweep doubles as the
    zero-retrace-across-depth-switch assertion;
  * **adversarial drafter** — a scrambled drafter tanks the acceptance
    rate but cannot change a single output token (the verifier owns
    the output; the drafter only owns throughput);
  * **the verify contract at its root** — eager `decode_multi` over
    k+1 positions is BITWISE equal to k+1 sequential `decode_step`s on
    a ragged per-slot pool (the per-token activation-scale property
    the whole scheme stands on);
  * **KV rollback** — the pure cache surgery (window zeroing + pos
    rewind, OOB drop at the pool edge), a served spec engine's pool
    cache byte-identical to the never-drafted baseline's, and the same
    surgery + scatter-insert on a forced 8-device host mesh
    (subprocess) matching the host result byte for byte;
  * **contracts** — spec_pair tier algebra, constructor errors raised
    early, warmup executable accounting.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import LM
from repro.serving import (Request, ServingEngine, SimClock,
                           build_engine, build_tiers, poisson_workload,
                           spec_pair)
from repro.serving.engine import LMLaneBackend
from repro.serving.spec import SpecDecodeBackend, _reset_pos, _rollback
from repro.serving.tiers import TierRouter

ARCH = "qwen3-1.7b"
KS = (1, 2, 4, 8)


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_config(ARCH, smoke=True)
    return cfg, LM(cfg).init(jax.random.PRNGKey(0))


def _mixed_workload(cfg, n=8, seed=11):
    """Ragged mixed-tier traffic: approximate lanes coexist with the
    speculative exact lane (staggered arrivals, short and long gens)."""
    return poisson_workload(n, rate=500.0, vocab=cfg.vocab,
                            prompt_len=(3, 6), max_new=(2, 10),
                            tier_mix=(("exact", None, 0.6),
                                      ("balanced", None, 0.2),
                                      ("economy", None, 0.2)), seed=seed)


@pytest.fixture(scope="module")
def spec_vs_base(cfg_params):
    """A spec engine (all draft depths pre-warmed) and the per-token
    exact baseline engine it must reproduce, over shared weights."""
    cfg, params = cfg_params
    tiers = build_tiers()
    _, v_tier = spec_pair(tiers)
    base_tiers = tuple(v_tier if t.name == "exact" else t for t in tiers)
    kw = dict(slots_per_tier=2, max_len=32, prompt_buckets=(6,),
              group_buckets=(1, 2))
    base = build_engine(cfg, params, tiers=base_tiers, **kw)
    base.warmup()
    spec = build_engine(cfg, params, tiers=tiers, spec_decode=2,
                        spec_ks=KS, **kw)
    n_warm = spec.warmup()
    # the retrace probe is a GLOBAL trace counter: re-arm the baseline's
    # mark now that the spec engine's warmup compiles are behind us
    base.warmup()
    return cfg, params, base, spec, n_warm


# ---------------------------------------------------------------------------
# differential bit-identity
# ---------------------------------------------------------------------------


def test_spec_tokens_bit_identical_all_depths(spec_vs_base):
    """Every draft depth, same mixed workload: token-for-token equal to
    the exact engine; depth switches are dict lookups (0 retraces)."""
    cfg, _, base, spec, _ = spec_vs_base
    wl = _mixed_workload(cfg)
    base_res = base.run(wl, clock=SimClock())
    sb = spec.lanes["exact"].backend
    for k in KS:
        sb.set_draft_k(k)
        res = spec.run(wl, clock=SimClock())
        for r in wl:
            assert res[r.rid].tokens == base_res[r.rid].tokens, \
                (f"k={k} rid={r.rid} tier={res[r.rid].tier}: spec "
                 f"output diverged from the exact engine")
    assert spec.steady_retraces() == 0, \
        "draft-depth switches retraced after warmup"
    assert base.steady_retraces() == 0
    # the drafter is the real approximate tier: it must actually agree
    # with the verifier often (otherwise spec decode is a no-op)
    assert sb.acceptance_rate > 0.3
    assert sb.tokens_per_round > 1.0


def test_spec_warmup_covers_all_depths(spec_vs_base):
    """Warmup accounting: every (tier x bucket) executable plus one
    fused spec round per configured draft depth."""
    _, _, _, spec, n_warm = spec_vs_base
    n_tiers = len(spec.lanes)
    # per lane: (1 prompt bucket x 2 group buckets) prefills + decode;
    # the spec lane adds one fused round per draft depth
    assert n_warm == n_tiers * (1 * 2 + 1) + len(KS)
    sb = spec.lanes["exact"].backend
    assert sb.draft_ks == KS


def test_spec_eos_truncates_mid_window(spec_vs_base):
    """An EOS landing inside the accept window stops the request at
    exactly the token the exact engine stops at."""
    cfg, _, base, spec, _ = spec_vs_base
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab, (4,))
    probe = base.run([Request(rid=900, prompt=prompt, max_new=8,
                              tier="exact")], clock=SimClock())
    eos = probe[900].tokens[3]       # becomes EOS on the re-run
    spec.lanes["exact"].backend.set_draft_k(4)
    req = lambda rid: [Request(rid=rid, prompt=prompt.copy(), max_new=8,
                               tier="exact", eos_id=eos)]
    r_b = base.run(req(901), clock=SimClock())
    r_s = spec.run(req(902), clock=SimClock())
    assert r_s[902].tokens == r_b[901].tokens
    assert r_s[902].tokens[-1] == eos
    assert len(r_s[902].tokens) <= 4     # truncated, not budget-drained


def test_adversarial_drafter_cannot_change_output(spec_vs_base,
                                                  cfg_params):
    """Scrambling the drafter's logits collapses acceptance to ~0 but
    the emitted tokens stay identical: the verifier owns the output."""
    cfg, params = cfg_params
    _, _, base, _, _ = spec_vs_base
    tiers = build_tiers()
    d_tier, v_tier = spec_pair(tiers)

    class _Scrambled:
        """Drafter double: same cache writes, argmax rotated away."""

        def __init__(self, lm):
            self._lm = lm

        def decode_step(self, params, caches, tok, pos):
            lg, caches = self._lm.decode_step(params, caches, tok, pos)
            return jnp.roll(lg, 1, axis=-1), caches

    vlm = LM(dataclasses.replace(cfg, cim=v_tier.cim))
    dlm = _Scrambled(LM(dataclasses.replace(cfg, cim=d_tier.cim)))
    lane = SpecDecodeBackend(vlm, dlm, params, draft_k=4, n_slots=2,
                             max_len=32, prompt_buckets=(6,),
                             group_buckets=(1, 2))
    eng = ServingEngine({"exact": lane}, TierRouter([v_tier]))
    eng.warmup()
    wl = [r for r in _mixed_workload(cfg) if r.tier == "exact"]
    res = eng.run(wl, clock=SimClock())
    base_res = base.run(wl, clock=SimClock())
    for r in wl:
        assert res[r.rid].tokens == base_res[r.rid].tokens, \
            f"rid={r.rid}: a bad drafter changed the output"
    assert lane.acceptance_rate < 0.1, \
        "scrambled drafts should almost never be accepted"
    assert eng.steady_retraces() == 0


# ---------------------------------------------------------------------------
# the verify contract: batched multi-position == sequential (eager)
# ---------------------------------------------------------------------------


def test_decode_multi_bitwise_equals_sequential(cfg_params):
    """Per-token activation scales make each row of a (B, K) verify
    pass row-pure: eager decode_multi over K positions is BITWISE the
    same logits and cache as K sequential eager decode_steps, on a
    ragged pool.  (Under jit the two are separate XLA programs and may
    differ in float low bits — DESIGN.md §12 documents why the token
    contract survives that.)"""
    cfg, params = cfg_params
    tiers = build_tiers(families=("exact",))
    _, v_tier = spec_pair(tiers)
    lm = LM(dataclasses.replace(cfg, cim=v_tier.cim))
    lane = LMLaneBackend(lm, params, n_slots=3, max_len=16,
                         prompt_buckets=(6,), group_buckets=(3,))
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, (l,)) for l in (6, 4, 2)]
    lane.admit(prompts, [0, 1, 2])
    k = 3
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (3, k + 1)), jnp.int32)
    fill = jnp.asarray(lane.slot_pos, jnp.int32)
    snap = jax.tree_util.tree_map(jnp.array, lane.caches)

    lg_m, c_m = lm.decode_multi(params, snap, toks, fill)

    c = jax.tree_util.tree_map(jnp.array, lane.caches)
    rows, pos = [], fill
    for i in range(k + 1):
        lg, c = lm.decode_step(params, c, toks[:, i:i + 1], pos)
        rows.append(lg[:, -1])
        pos = pos + 1
    lg_s = jnp.stack(rows, axis=1)

    assert np.array_equal(np.asarray(lg_m, np.float32),
                          np.asarray(lg_s, np.float32)), \
        "batched verify logits are not bitwise sequential"
    for a, b in zip(jax.tree_util.tree_leaves(c_m),
                    jax.tree_util.tree_leaves(c)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "batched verify cache writes are not bitwise sequential"


# ---------------------------------------------------------------------------
# KV rollback
# ---------------------------------------------------------------------------


def _toy_caches(rng, b=3, t=8, d=4, layers=2):
    """A cache pytree in the real layout: prefix per-layer dicts with
    (B, t, d) leaves, body dict of stacked (L, B, t, d) leaves."""
    mk = lambda *s: rng.normal(size=s).astype(np.float32)
    prefix = [{"k": mk(b, t, d), "v": mk(b, t, d),
               "pos": np.full(b, 5, np.int32)}]
    body = {"0": {"k": mk(layers, b, t, d), "v": mk(layers, b, t, d),
                  "pos": np.full((layers, b), 5, np.int32)}}
    return {"prefix": prefix, "body": body}


def test_rollback_zeroes_window_rewinds_pos():
    """_rollback zeroes exactly [new_fill, new_fill+width) per row (OOB
    entries dropped at the pool edge, other entries untouched) and
    rewinds every pos leaf — prefix and stacked body alike."""
    rng = np.random.default_rng(0)
    caches = _toy_caches(rng, b=3, t=8)
    width = 3
    new_fill = np.asarray([2, 6, 0], np.int32)   # row 1 overhangs t=8
    out = _rollback(jax.tree_util.tree_map(jnp.asarray, caches),
                    jnp.asarray(new_fill), width)

    def expect(arr, batch_axis):
        exp = np.array(arr)
        for b, f in enumerate(new_fill):
            idx = [slice(None)] * exp.ndim
            idx[batch_axis] = b
            idx[batch_axis + 1] = slice(f, min(f + width, exp.shape[
                batch_axis + 1]))
            exp[tuple(idx)] = 0
        return exp

    for name in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(out["prefix"][0][name]),
            expect(caches["prefix"][0][name], 0))
        np.testing.assert_array_equal(
            np.asarray(out["body"]["0"][name]),
            expect(caches["body"]["0"][name], 1))
    np.testing.assert_array_equal(np.asarray(out["prefix"][0]["pos"]),
                                  new_fill)
    np.testing.assert_array_equal(
        np.asarray(out["body"]["0"]["pos"]),
        np.broadcast_to(new_fill, (2, 3)))


def test_reset_pos_touches_only_pos():
    rng = np.random.default_rng(1)
    caches = _toy_caches(rng)
    fill = jnp.asarray([1, 2, 3], jnp.int32)
    out = _reset_pos(jax.tree_util.tree_map(jnp.asarray, caches), fill)
    for name in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(out["prefix"][0][name]),
                                      caches["prefix"][0][name])
        np.testing.assert_array_equal(np.asarray(out["body"]["0"][name]),
                                      caches["body"]["0"][name])
    np.testing.assert_array_equal(np.asarray(out["prefix"][0]["pos"]),
                                  [1, 2, 3])
    np.testing.assert_array_equal(np.asarray(out["body"]["0"]["pos"]),
                                  np.broadcast_to([1, 2, 3], (2, 3)))


def test_rolled_back_cache_byte_identical_to_never_drafted(cfg_params):
    """After serving the same request, the spec lane's pool cache is
    byte-for-byte the baseline lane's: the rollback restores "entries
    >= fill are zero" exactly, and the verify pass wrote the same K/V
    the sequential decode would have."""
    cfg, params = cfg_params
    tiers = build_tiers(families=("exact", "mitchell"))
    _, v_tier = spec_pair(tiers)
    kw = dict(slots_per_tier=1, max_len=32, prompt_buckets=(6,),
              group_buckets=(1,))
    base = build_engine(cfg, params, tiers=(v_tier,), **kw)
    base.warmup()
    spec = build_engine(cfg, params, tiers=tiers, spec_decode=3, **kw)
    spec.warmup()
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, (5,))
    req = lambda: [Request(rid=0, prompt=prompt.copy(), max_new=9,
                           tier="exact")]
    r_b = base.run(req(), clock=SimClock())
    r_s = spec.run(req(), clock=SimClock())
    assert r_s[0].tokens == r_b[0].tokens
    bb = base.lanes["exact"].backend
    sb = spec.lanes["exact"].backend
    np.testing.assert_array_equal(bb.slot_pos, sb.slot_pos)
    leaves_b = jax.tree_util.tree_leaves(bb.caches)
    leaves_s = jax.tree_util.tree_leaves(sb.caches)
    assert len(leaves_b) == len(leaves_s)
    for a, b in zip(leaves_b, leaves_s):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "spec pool cache != never-drafted pool cache"


def test_rollback_and_insert_on_host_mesh():
    """The cache ops spec decoding leans on — the lane's scatter-insert
    and the rollback surgery — produce byte-identical results on a
    forced 8-device host mesh (DP-sharded slot pool) and on one device."""
    from _hostmesh import run_host_mesh

    out = run_host_mesh("""
        import dataclasses, json
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models.transformer import LM
        from repro.serving import build_tiers
        from repro.serving.engine import LMLaneBackend
        from repro.serving.spec import _rollback
        from repro.launch.mesh import make_host_mesh

        cfg = get_config("qwen3-1.7b", smoke=True)
        tier = build_tiers(families=("exact",))[0]
        lm = LM(dataclasses.replace(cfg, cim=tier.cim))
        params = LM(cfg).init(jax.random.PRNGKey(0))
        mesh = make_host_mesh()           # (data=8, model=1)
        kw = dict(n_slots=8, max_len=16, prompt_buckets=(6,),
                  group_buckets=(4,))
        host = LMLaneBackend(lm, params, **kw)
        shrd = LMLaneBackend(lm, params, mesh=mesh, **kw)
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, cfg.vocab, (l,)) for l in (6, 4, 2)]
        host.admit(prompts, [0, 3, 5])
        shrd.admit(prompts, [0, 3, 5])
        insert_eq = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(host.caches),
                            jax.tree_util.tree_leaves(shrd.caches)))
        new_fill = jnp.asarray(np.maximum(host.slot_pos - 1, 0),
                               jnp.int32)
        rb_h = _rollback(host.caches, new_fill, 3)
        with mesh:
            rb_s = _rollback(shrd.caches, new_fill, 3)
        rollback_eq = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(rb_h),
                            jax.tree_util.tree_leaves(rb_s)))
        print(json.dumps({"insert_equal": insert_eq,
                          "rollback_equal": rollback_eq}))
    """)
    assert out["insert_equal"], "mesh scatter-insert != host"
    assert out["rollback_equal"], "mesh rollback != host"


# ---------------------------------------------------------------------------
# contracts
# ---------------------------------------------------------------------------


def test_spec_pair_contracts():
    tiers = build_tiers()
    d, v = spec_pair(tiers)
    assert v.name == "exact" and v.cim.per_token
    assert v.nmed == 0.0
    approx = [t for t in tiers if t.name != "exact"]
    assert d.name == min(approx,        # cheapest-energy approximate rung
                         key=lambda t: t.energy_per_mac_j).name
    d2, _ = spec_pair(tiers, drafter="economy")
    assert d2.name == "economy"
    with pytest.raises(KeyError):
        spec_pair(tiers, drafter="no-such-tier")
    with pytest.raises(ValueError):
        spec_pair([t for t in tiers if t.name != "exact"])
    d3, v3 = spec_pair(build_tiers(families=("exact",)))
    assert d3.name == "exact" and not d3.cim.per_token   # degenerate
    assert v3.cim.per_token


def test_spec_backend_constructor_contracts(cfg_params):
    cfg, params = cfg_params
    tiers = build_tiers()
    d_tier, v_tier = spec_pair(tiers)
    ex = next(t for t in tiers if t.name == "exact")
    vlm = LM(dataclasses.replace(cfg, cim=v_tier.cim))
    dlm = LM(dataclasses.replace(cfg, cim=d_tier.cim))
    kw = dict(n_slots=1, max_len=16, prompt_buckets=(4,),
              group_buckets=(1,))
    with pytest.raises(ValueError, match="mesh"):
        SpecDecodeBackend(vlm, dlm, params, mesh=object(), **kw)
    with pytest.raises(ValueError, match="per_token"):
        SpecDecodeBackend(LM(dataclasses.replace(cfg, cim=ex.cim)),
                          dlm, params, **kw)
    with pytest.raises(ValueError, match="depth"):
        SpecDecodeBackend(vlm, dlm, params, draft_k=0, **kw)
    b = SpecDecodeBackend(vlm, dlm, params, draft_k=2, draft_ks=(1, 2),
                          **kw)
    assert b.draft_ks == (1, 2)
    with pytest.raises(ValueError, match="not pre-built"):
        b.set_draft_k(3)                 # unwarmed depth would retrace
    b.set_draft_k(1)
    assert b.draft_k == 1
