"""Distribution-layer tests: logical rules, uneven-dim fallback, and a
scaled-down dry-run (8 host devices, subprocess so the main test process
keeps its single-device view)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import logical_to_spec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_logical_rules_resolution():
    mesh = _FakeMesh({"data": 4, "model": 4})
    assert logical_to_spec(("embed", "ff"), (64, 128), mesh) == P("data", "model")
    assert logical_to_spec(("vocab", "embed"), (1000, 64), mesh) == \
        P("model", "data")
    # batch composes pod+data when present
    mesh3 = _FakeMesh({"pod": 2, "data": 4, "model": 4})
    assert logical_to_spec(("batch", None), (32, 7), mesh3) == \
        P(("pod", "data"), None)


def test_uneven_dims_fall_back_to_replication():
    mesh = _FakeMesh({"data": 4, "model": 4})
    # 40 heads on a 16-way axis -> replicated (qwen2.5 case, documented)
    assert logical_to_spec(("embed", "heads", None), (64, 10, 16), mesh) == \
        P("data", None, None)
    # dim smaller than the axis
    assert logical_to_spec(("vocab", None), (3, 8), mesh) == P(None, None)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp
    sys.path.insert(0, {repo!r} + "/src")
    from repro.configs import get_config, input_specs
    from repro.models.config import ShapeConfig
    from repro.models.transformer import LM
    from repro.parallel.sharding import (param_shardings, batch_sharding,
                                         replicated)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config({arch!r}, smoke=True)
    model = LM(cfg)
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = param_shardings(model, pshape, mesh)
    def loss(p, t, k):
        return model.loss_fn(p, {{"tokens": t}}, k)[0]
    tok = jax.ShapeDtypeStruct((8, 64), jnp.int32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    with mesh:
        compiled = jax.jit(jax.grad(loss),
                           in_shardings=(pshard, batch_sharding(mesh, 2),
                                         replicated(mesh)),
                           out_shardings=pshard).lower(
            pshape, tok, key).compile()
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    print(json.dumps({{
        "ok": True,
        "temp": ma.temp_size_in_bytes,
        "has_collectives": ("all-reduce" in txt or "all-gather" in txt),
    }}))
""")


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-v2-lite-16b",
                                  "recurrentgemma-9b"])
def test_sharded_grad_compiles_on_8_devices(arch):
    code = _SUBPROC.format(repo=REPO, arch=arch)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["has_collectives"]


def test_hlo_analysis_counts_loop_bodies():
    from repro.launch.hlo_analysis import analyze
    import jax.numpy as jnp

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def scanned(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    c = jax.jit(scanned).lower(a, a).compile()
    r = analyze(c.as_text())
    want = 7 * 2 * 256 ** 3
    assert abs(r["flops"] - want) / want < 0.02
    # XLA's own aggregate misses the trip count (documented motivation)
    from repro.launch.hlo_analysis import xla_cost_dict

    xla = xla_cost_dict(c).get("flops", 0.0)
    assert xla < 0.5 * want


_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp
    sys.path.insert(0, {repo!r} + "/src")
    from repro.configs import get_config
    from repro.data.pipeline import TokenStream
    from repro.models.transformer import LM
    from repro.optim import adamw
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config("qwen3-1.7b", smoke=True)
    lm = LM(cfg)

    def build(mesh):
        data = TokenStream(cfg.vocab, 32, 8, seed=0)
        return Trainer(lm, adamw.AdamWConfig(lr=1e-3, state_bits=32,
                                             warmup_steps=1, total_steps=4),
                       mesh, TrainerConfig(steps=4, ckpt_every=2,
                                           ckpt_dir={ckpt!r}), data)

    # train on 4x2, checkpoint
    t1 = build(jax.make_mesh((4, 2), ("data", "model")))
    out1 = t1.run()
    # "lose" half the fleet: resume on 2x2 with resharded restore
    t2 = build(jax.make_mesh((2, 2), ("data", "model")))
    params, opt = t2.init_state()
    step, params, opt = t2.try_resume(params, opt)
    l = jax.tree_util.tree_leaves(params)[0]
    print(json.dumps({{"ok": True, "resumed_step": step,
                       "n_shards": len(l.sharding.device_set)}}))
""")


def test_elastic_resume_across_mesh_sizes(tmp_path):
    """Checkpoint on a 4x2 mesh, restore on 2x2 (elastic downsize)."""
    code = _ELASTIC.format(repo=REPO, ckpt=str(tmp_path / "elastic"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["resumed_step"] == 4
    assert res["n_shards"] == 4          # placed on the NEW (smaller) mesh
