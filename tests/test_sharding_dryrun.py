"""Distribution-layer tests: logical rules, uneven-dim fallback, and a
scaled-down dry-run (8 host devices, subprocess so the main test process
keeps its single-device view — via the shared _hostmesh helper, which
also preserves any pre-existing XLA_FLAGS content)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _hostmesh import run_host_mesh
from repro.parallel.sharding import batch_sharding, logical_to_spec


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_logical_rules_resolution():
    mesh = _FakeMesh({"data": 4, "model": 4})
    assert logical_to_spec(("embed", "ff"), (64, 128), mesh) == P("data", "model")
    assert logical_to_spec(("vocab", "embed"), (1000, 64), mesh) == \
        P("model", "data")
    # batch composes pod+data when present
    mesh3 = _FakeMesh({"pod": 2, "data": 4, "model": 4})
    assert logical_to_spec(("batch", None), (32, 7), mesh3) == \
        P(("pod", "data"), None)


def test_uneven_dims_fall_back_to_replication():
    mesh = _FakeMesh({"data": 4, "model": 4})
    # 40 heads on a 16-way axis -> replicated (qwen2.5 case, documented)
    assert logical_to_spec(("embed", "heads", None), (64, 10, 16), mesh) == \
        P("data", None, None)
    # dim smaller than the axis
    assert logical_to_spec(("vocab", None), (3, 8), mesh) == P(None, None)


def test_axis_reuse_dedup():
    """A mesh axis may carry at most ONE dim of a tensor (the `used`
    set): the second logical name wanting an already-taken axis
    replicates instead of double-sharding."""
    mesh = _FakeMesh({"data": 4, "model": 4})
    # embed takes "data" first; batch = ("pod","data") -> data already
    # used -> the batch dim replicates
    assert logical_to_spec(("embed", "batch"), (64, 32), mesh) == \
        P("data", None)
    # two model-axis names on one tensor: first wins, second replicates
    assert logical_to_spec(("ff", "vocab"), (64, 64), mesh) == \
        P("model", None)
    mesh3 = _FakeMesh({"pod": 2, "data": 4, "model": 4})
    # batch grabs pod+data; a later embed dim finds data used
    assert logical_to_spec(("batch", "embed"), (32, 64), mesh3) == \
        P(("pod", "data"), None)


def test_axis_reuse_partial_composite():
    """When part of a composite axis group is taken, only the free
    axes remain — and the dim must divide THEIR product."""
    mesh3 = _FakeMesh({"pod": 2, "data": 4, "model": 4})
    # embed holds "data"; batch falls back to ("pod",): 32 % 2 == 0
    assert logical_to_spec(("embed", "batch"), (64, 32), mesh3) == \
        P("data", "pod")
    # ...but an odd batch dim can't ride the leftover pod axis
    assert logical_to_spec(("embed", "batch"), (64, 31), mesh3) == \
        P("data", None)


def test_non_divisible_dim_replicates_not_errors():
    mesh = _FakeMesh({"data": 4, "model": 4})
    # 66 % 4 != 0 on every axis -> both dims replicate, no raise
    assert logical_to_spec(("embed", "ff"), (66, 67), mesh) == P(None, None)


def test_batch_sharding_non_divisible_dim0():
    """batch_sharding with dim0 not divisible by the batch axes falls
    back to full replication (long_500k's global batch of 1)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert batch_sharding(mesh, 2, dim0=8).spec == P("data", None)
    # dim0=3 on a 1-wide data axis still divides; force non-divisible
    # via a fake 4-wide mesh through the spec-only path
    fake = _FakeMesh({"data": 4, "model": 2})
    from repro.parallel.sharding import batch_axes
    assert batch_axes(fake, 6) == ()          # 6 % 4 != 0 -> replicate
    assert batch_axes(fake, 8) == ("data",)
    assert batch_axes(fake, None) == ("data",)


_SUBPROC = """
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_config, input_specs
    from repro.models.config import ShapeConfig
    from repro.models.transformer import LM
    from repro.parallel.sharding import (param_shardings, batch_sharding,
                                         replicated)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config({arch!r}, smoke=True)
    model = LM(cfg)
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = param_shardings(model, pshape, mesh)
    def loss(p, t, k):
        return model.loss_fn(p, {{"tokens": t}}, k)[0]
    tok = jax.ShapeDtypeStruct((8, 64), jnp.int32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    with mesh:
        compiled = jax.jit(jax.grad(loss),
                           in_shardings=(pshard, batch_sharding(mesh, 2),
                                         replicated(mesh)),
                           out_shardings=pshard).lower(
            pshape, tok, key).compile()
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    print(json.dumps({{
        "ok": True,
        "temp": ma.temp_size_in_bytes,
        "has_collectives": ("all-reduce" in txt or "all-gather" in txt),
    }}))
"""


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-v2-lite-16b",
                                  "recurrentgemma-9b"])
def test_sharded_grad_compiles_on_8_devices(arch):
    res = run_host_mesh(_SUBPROC.format(arch=arch))
    assert res["ok"] and res["has_collectives"]


def test_hlo_analysis_counts_loop_bodies():
    from repro.launch.hlo_analysis import analyze
    import jax.numpy as jnp

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def scanned(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    c = jax.jit(scanned).lower(a, a).compile()
    r = analyze(c.as_text())
    want = 7 * 2 * 256 ** 3
    assert abs(r["flops"] - want) / want < 0.02
    # XLA's own aggregate misses the trip count (documented motivation)
    from repro.launch.hlo_analysis import xla_cost_dict

    xla = xla_cost_dict(c).get("flops", 0.0)
    assert xla < 0.5 * want


_ELASTIC = """
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.data.pipeline import TokenStream
    from repro.models.transformer import LM
    from repro.optim import adamw
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config("qwen3-1.7b", smoke=True)
    lm = LM(cfg)

    def build(mesh):
        data = TokenStream(cfg.vocab, 32, 8, seed=0)
        return Trainer(lm, adamw.AdamWConfig(lr=1e-3, state_bits=32,
                                             warmup_steps=1, total_steps=4),
                       mesh, TrainerConfig(steps=4, ckpt_every=2,
                                           ckpt_dir={ckpt!r}), data)

    # train on 4x2, checkpoint
    t1 = build(jax.make_mesh((4, 2), ("data", "model")))
    out1 = t1.run()
    # "lose" half the fleet: resume on 2x2 with resharded restore
    t2 = build(jax.make_mesh((2, 2), ("data", "model")))
    params, opt = t2.init_state()
    step, params, opt = t2.try_resume(params, opt)
    l = jax.tree_util.tree_leaves(params)[0]
    print(json.dumps({{"ok": True, "resumed_step": step,
                       "n_shards": len(l.sharding.device_set)}}))
"""


def test_elastic_resume_across_mesh_sizes(tmp_path):
    """Checkpoint on a 4x2 mesh, restore on 2x2 (elastic downsize)."""
    res = run_host_mesh(_ELASTIC.format(ckpt=str(tmp_path / "elastic")))
    assert res["ok"] and res["resumed_step"] == 4
    assert res["n_shards"] == 4          # placed on the NEW (smaller) mesh
