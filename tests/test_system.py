"""End-to-end behaviour: the OpenACM compile flow from config to
executable macro, integrated into a model forward pass."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CiMConfig, compile_macro
from repro.core.dse import best_under_budget


def test_compile_macro_end_to_end():
    m = compile_macro(CiMConfig(family="log_our", bits=8, mode="surrogate"))
    assert m.metrics.nmed < 5e-3           # paper Table IV: 4.40e-3
    assert m.ppa.energy_per_mac_j > 0
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 4))
    out = m.matmul(x, w, key=jax.random.PRNGKey(2))
    assert out.shape == (8, 4) and bool(jnp.isfinite(out).all())


def test_energy_accuracy_tradeoff_visible():
    """The paper's core claim: approx families trade accuracy for energy."""
    exact = compile_macro(CiMConfig(family="exact", bits=32))
    log = compile_macro(CiMConfig(family="log_our", bits=32))
    saving = log.ppa.saving_vs(exact.ppa)
    assert 0.60 <= saving <= 0.70          # "reducing power by nearly 64%"


def test_dse_selects_cheaper_design_under_loose_budget():
    tight = best_under_budget(bits=8, max_nmed=1e-12)
    loose = best_under_budget(bits=8, max_nmed=5e-2)
    assert tight.spec.family == "exact"
    assert loose.spec.family != "exact"
    assert loose.energy_per_mac_j <= tight.energy_per_mac_j


def test_macro_in_model_layer():
    """The technique as a first-class model feature (DESIGN.md §4)."""
    from repro.configs import get_config
    from repro.models.transformer import LM

    cfg = get_config("qwen3-1.7b", smoke=True,
                     cim=CiMConfig(family="appro42", bits=8,
                                   mode="surrogate"))
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 32)))
    loss, _ = lm.loss_fn(params, {"tokens": toks}, jax.random.PRNGKey(1))
    assert bool(jnp.isfinite(loss))
