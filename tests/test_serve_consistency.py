"""Prefill + incremental decode must reproduce the full-forward logits
(teacher-forced) for every cache mechanism in the zoo: GQA KV cache,
MLA latent cache (absorbed decode), RG-LRU state + ring-buffer window,
xLSTM states, whisper enc-dec cross cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import LM

ARCHS = ["qwen3-1.7b", "deepseek-v2-lite-16b", "recurrentgemma-9b",
         "xlstm-125m", "whisper-medium", "llama-3.2-vision-11b"]


def _batch_for(cfg, tokens):
    batch = {"tokens": tokens}
    b = tokens.shape[0]
    if cfg.vision is not None:
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(5),
            (b, cfg.vision.n_tokens, cfg.vision.d_vision))
    if cfg.encoder is not None:
        batch["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(6), (b, cfg.encoder.n_frames, cfg.d_model)
        ).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_config(arch, smoke=True)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    b, s, n_dec = 2, 24, 4
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (b, s + n_dec)))

    # full teacher-forced forward (no cache): logits after s+i tokens
    full_logits = []
    for t in range(s, s + n_dec):
        batch = _batch_for(cfg, toks[:, :t])
        lp, _ = lm.prefill(params, dict(batch, max_len=s + n_dec))
        full_logits.append(lp[:, -1])

    # prefill once, then decode token by token
    batch = _batch_for(cfg, toks[:, :s])
    lp, caches = lm.prefill(params, dict(batch, max_len=s + n_dec))
    got = [lp[:, -1]]
    for i in range(n_dec - 1):
        pos = jnp.int32(s + i)
        lp, caches = lm.decode_step(params, caches, toks[:, s + i:s + i + 1],
                                    pos)
        got.append(lp[:, -1])

    for i in range(n_dec):
        np.testing.assert_allclose(
            np.asarray(got[i], np.float32),
            np.asarray(full_logits[i], np.float32),
            rtol=0.12, atol=0.12,
            err_msg=f"{arch}: decode step {i} diverged from full forward")


def test_window_ring_buffer_long_decode():
    """Local attention must stay consistent past the window boundary."""
    cfg = get_config("recurrentgemma-9b", smoke=True)   # window = 32
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    b, total = 1, 48                                     # crosses the window
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab, (b, total)))
    s = 8
    lp, caches = lm.prefill(params, {"tokens": toks[:, :s],
                                     "max_len": total})
    for i in range(total - s - 1):
        lp, caches = lm.decode_step(params, caches,
                                    toks[:, s + i:s + i + 1],
                                    jnp.int32(s + i))
    # reference: full forward over the same prefix (total-1 tokens seen)
    ref, _ = lm.prefill(params, {"tokens": toks[:, :total - 1],
                                 "max_len": total})
    np.testing.assert_allclose(np.asarray(lp[:, -1], np.float32),
                               np.asarray(ref[:, -1], np.float32),
                               rtol=0.12, atol=0.12)
